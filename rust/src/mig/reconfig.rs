//! Online MIG reconfiguration: windowed rate telemetry, a hysteresis
//! controller with an amortized reconfig-cost model, and a rate-aware
//! partition/allocation planner.
//!
//! PREBA's characterization says the right slicing is workload-dependent;
//! the offline `mig::planner` freezes one answer. Real traffic is diurnal
//! and bursty (`workload::trace`), so the partition — both the slice
//! *geometry* (`MigConfig`) and, under multi-tenancy, the *assignment* of
//! slices to tenants — should track the observed arrival rate. This is the
//! "reconfigurable machine scheduling" problem (Tan et al.,
//! arXiv:2109.11067): repartitioning has a real cost (MIG instances must
//! drain before they can be destroyed/re-created), so the controller only
//! moves when the predicted gain amortizes that cost, and never twice
//! within a cooldown window.
//!
//! Three layers, usable independently:
//! * [`RateWatcher`] — windowed arrival-rate estimation with EWMA
//!   smoothing (the `workload::trace::windowed_rates` telemetry, online).
//! * [`plan_for_rates`] — for observed per-tenant rates, the best
//!   (geometry, slice allocation) under the same analytic latency model
//!   the DES implements (Time_knee/n batching wait + service + an M/D/c
//!   utilization inflation).
//! * [`ReconfigController`] — the decision gate: EWMA telemetry in,
//!   `Option<Plan>` out, with hysteresis deadband, cooldown, and the
//!   amortized cost-benefit check.
//!
//! The DES drivers (`server::sim_driver` single-tenant geometry,
//! `server::multi` multi-tenant slice reallocation) turn an emitted plan
//! into first-class drain/restart events.
//!
//! Cluster-scale planning is **pluggable** ([`planners`]): the greedy
//! fast path, a greedy-seeded simulated-annealing slow path, and a
//! branch-and-bound exact solver all implement the [`Planner`] trait
//! behind [`ReconfigPolicy::planner`], and every emitted plan replays
//! through the shared [`validate_plan`] checker before it commits.

pub mod planners;

pub use planners::{
    plan_cost, AnnealPlanner, ExactPlanner, GreedyPlanner, OwnedInstance, PlanInstance, Planner,
    PlannerKind,
};

use crate::clock::{secs, to_secs, Nanos};
use crate::mig::partition::GpuClass;
use crate::mig::{MigConfig, ServiceModel, Slice};
use crate::models::ModelId;

/// Predicted-latency scale for infeasible (rate >= capacity) operating
/// points, ms: an overloaded point scores `INFEASIBLE_MS × rho`. Finite
/// and strictly increasing in rho, so ordering between two overloaded
/// plans works at ANY depth of overload — the cross-GPU planner relies
/// on `p95(n) - p95(n+1) > 0` to price a rescue migration even when both
/// operating points are far past saturation.
const INFEASIBLE_MS: f64 = 60_000.0;

/// Controller knobs. Defaults suit the experiment scenarios (periods of
/// seconds); production deployments would scale window/cooldown up with
/// their traffic periods.
#[derive(Debug, Clone)]
pub struct ReconfigPolicy {
    /// Arrival-rate estimation window, seconds (also the decision cadence).
    pub window_s: f64,
    /// EWMA weight of the newest window (1.0 = no smoothing).
    pub ewma_alpha: f64,
    /// Minimum time between two reconfigurations, seconds. Also the
    /// commitment horizon the cost model amortizes over.
    pub cooldown_s: f64,
    /// Hysteresis deadband: a candidate plan must beat the current plan's
    /// predicted worst SLA ratio by at least this relative margin.
    pub min_gain: f64,
    /// Fixed repartition outage per move (instance destroy + create +
    /// server restart), seconds, charged after the affected slices drain.
    pub repartition_s: f64,
    /// Outage of a cross-GPU tenant migration (new residency: model
    /// weights shipped and a fresh server spun up on a GPU the tenant was
    /// not serving from), seconds. ≫ `repartition_s` — resizing slices
    /// in place only repartitions, migrating pays the transfer too.
    pub migration_s: f64,
    /// Utilization target the allocator sizes slice counts for.
    pub target_util: f64,
    /// Energy-aware fleet consolidation
    /// ([`ClusterReconfigController::tick_consolidation`]): under
    /// sustained low load, shrink over-provisioned tenants and drain the
    /// lightest GPU so it can be powered down (idle-power elision); wake
    /// parked GPUs again when provisioned capacity no longer covers
    /// demand. Off by default — the rate-driven planner alone then owns
    /// every decision.
    pub consolidate: bool,
    /// Fleet slice-utilization (demanded slices / provisioned slices)
    /// below which a window counts as "low load". Consolidation keeps
    /// every tenant provisioned for `rate / consolidate_util`, so the
    /// surviving capacity holds ~1/consolidate_util× headroom over the
    /// demand that justified the power-down.
    pub consolidate_util: f64,
    /// Consecutive low-load windows required before a power-down — the
    /// sustained-low-load hysteresis (plus the shared `cooldown_s`) that
    /// keeps consolidation from fighting the rate-driven planner.
    pub consolidate_windows: usize,
    /// Planning algorithm [`ClusterReconfigController::tick`] runs each
    /// window: the greedy fast path (default), the greedy-seeded
    /// simulated-annealing slow path, or the branch-and-bound exact
    /// solver for small fleets. The hysteresis/cooldown/amortized-cost
    /// commit gates sit outside this choice, so swapping planners never
    /// changes the no-thrash contract.
    pub planner: PlannerKind,
    /// Proposal budget of the [`AnnealPlanner`] slow path. A pure
    /// iteration count — wall-clock plays no part — so annealed plans
    /// stay deterministic at any `--jobs`; 0 degenerates to greedy.
    pub anneal_iters: usize,
}

impl Default for ReconfigPolicy {
    fn default() -> Self {
        ReconfigPolicy {
            window_s: 0.75,
            ewma_alpha: 0.5,
            cooldown_s: 1.5,
            min_gain: 0.15,
            repartition_s: 0.15,
            migration_s: 0.75,
            target_util: 0.85,
            consolidate: false,
            consolidate_util: 0.5,
            consolidate_windows: 3,
            planner: PlannerKind::Greedy,
            anneal_iters: 2_000,
        }
    }
}

/// One tenant the controller plans for.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub model: ModelId,
    /// End-to-end p95 SLA, ms.
    pub sla_ms: f64,
    /// Representative input length, seconds (0 for vision).
    pub len_s: f64,
}

impl TenantSpec {
    pub fn new(model: ModelId, sla_ms: f64) -> TenantSpec {
        TenantSpec { model, sla_ms, len_s: crate::mig::planner::default_len(model) }
    }
}

/// A concrete partition decision: slice geometry + per-tenant slice counts
/// (`alloc[i]` vGPUs for tenant `i`; the counts need not exhaust the
/// partition, but the planner always hands out every slice).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Plan {
    pub mig: MigConfig,
    pub alloc: Vec<usize>,
}

impl Plan {
    /// Single-tenant plan owning the whole partition.
    pub fn single(mig: MigConfig) -> Plan {
        Plan { mig, alloc: vec![mig.vgpus()] }
    }

    pub fn slices(&self) -> usize {
        self.alloc.iter().sum()
    }
}

impl std::fmt::Display for Plan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}[", self.mig.name())?;
        for (i, a) in self.alloc.iter().enumerate() {
            if i > 0 {
                f.write_str("/")?;
            }
            write!(f, "{a}")?;
        }
        f.write_str("]")
    }
}

/// One committed reconfiguration (timeline entry).
#[derive(Debug, Clone)]
pub struct ReconfigEvent {
    pub at: Nanos,
    pub plan: Plan,
    /// Smoothed per-tenant rates that justified the move, queries/s.
    pub rates: Vec<f64>,
    /// Predicted worst-tenant p95 improvement, ms.
    pub predicted_gain_ms: f64,
}

/// Windowed arrival-rate estimator with EWMA smoothing.
#[derive(Debug, Clone)]
pub struct RateWatcher {
    window_start: Nanos,
    count: u64,
    alpha: f64,
    ewma: f64,
    primed: bool,
}

impl RateWatcher {
    pub fn new(alpha: f64) -> RateWatcher {
        RateWatcher { window_start: 0, count: 0, alpha, ewma: 0.0, primed: false }
    }

    /// Count one arrival in the current window.
    pub fn observe(&mut self) {
        self.count += 1;
    }

    /// Close the window ending at `now`; returns the smoothed estimate.
    pub fn roll(&mut self, now: Nanos) -> f64 {
        let span_s = to_secs(now.saturating_sub(self.window_start)).max(1e-9);
        let inst = self.count as f64 / span_s;
        if self.primed {
            self.ewma = self.alpha * inst + (1.0 - self.alpha) * self.ewma;
        } else {
            self.ewma = inst;
            self.primed = true;
        }
        self.window_start = now;
        self.count = 0;
        self.ewma
    }

    /// Current smoothed rate, queries/s.
    pub fn rate(&self) -> f64 {
        self.ewma
    }
}

/// Analytic p95 prediction for `rate_qps` offered to `n_vgpus` slices of
/// `mig`'s geometry — the same latency structure the DES produces: a
/// request waits for its batch (up to the Time_knee/n deadline the
/// batching policy uses), executes, and sees M/D/c-style queueing
/// inflation as utilization rises. Deliberately mirrors the simulator so
/// the controller's ranking matches simulated outcomes.
pub fn predicted_p95_ms(spec: &TenantSpec, mig: MigConfig, n_vgpus: usize, rate_qps: f64) -> f64 {
    predicted_p95_ms_gpcs(spec, mig.gpcs_per_vgpu(), n_vgpus, rate_qps)
}

/// [`predicted_p95_ms`] for a raw slice size, not tied to a homogeneous
/// [`MigConfig`] — the cluster planner mixes instance profiles per GPU.
pub fn predicted_p95_ms_gpcs(
    spec: &TenantSpec,
    gpcs: usize,
    n_vgpus: usize,
    rate_qps: f64,
) -> f64 {
    predicted_p95_ms_gpcs_scaled(spec, gpcs, n_vgpus, rate_qps, 1.0)
}

/// [`predicted_p95_ms_gpcs`] with a curve-derived service-time scale
/// (`>= 1.0` in practice): execution times are multiplied by it and the
/// effective plateau divided, so a curve-aware controller sees both the
/// longer batches and the earlier saturation the curves imply. Monotone
/// non-decreasing in `service_scale`; `1.0` is bit-identical to the
/// unscaled predictor.
pub fn predicted_p95_ms_gpcs_scaled(
    spec: &TenantSpec,
    gpcs: usize,
    n_vgpus: usize,
    rate_qps: f64,
    service_scale: f64,
) -> f64 {
    if n_vgpus == 0 {
        // Strictly worse than ANY served operating point at this rate —
        // including a single slice overloaded arbitrarily deep — so the
        // planner always prices the first slice as a gain.
        return 2.0
            * predicted_p95_ms_gpcs_scaled(spec, gpcs, 1, rate_qps, service_scale)
                .max(INFEASIBLE_MS);
    }
    let sm = ServiceModel::new(spec.model.spec(), gpcs);
    let len = spec.len_s;
    let per_vgpu = rate_qps / n_vgpus as f64;
    let rho = per_vgpu / (sm.plateau_qps(len) / service_scale);
    if rho >= 0.999 {
        return INFEASIBLE_MS * rho;
    }
    let knee = sm.knee(len);
    // The drivers' dynamic policy: Batch_max = knee, Time_queue = T(knee)/n.
    let tq_s = sm.exec_secs(knee, len) * service_scale / n_vgpus as f64;
    // Batch the offered rate fills before the deadline fires.
    let fill = (per_vgpu * tq_s).floor() as usize;
    let b = (fill + 1).clamp(1, knee);
    // Head-of-line wait: the deadline when the queue can't fill the knee
    // in time, else the knee fill time.
    let wait_s = if b >= knee { (knee as f64 / per_vgpu.max(1e-9)).min(tq_s) } else { tq_s };
    let exec_s = sm.exec_secs(b, len) * service_scale;
    let inflation = 1.0 + rho * rho / (2.0 * (1.0 - rho));
    (wait_s + exec_s * inflation) * 1e3 * 1.10
}

/// Allocate `mig`'s slices across tenants for the observed rates: everyone
/// gets at least one slice, then each remaining slice goes to the tenant
/// with the largest unmet demand (in slices, sized at `target_util`).
/// Deterministic: ties break toward the lowest tenant index. `None` when
/// the partition has fewer slices than tenants.
pub fn alloc_for_rates(
    tenants: &[TenantSpec],
    rates: &[f64],
    mig: MigConfig,
    target_util: f64,
) -> Option<Vec<usize>> {
    let n = mig.vgpus();
    let t = tenants.len();
    if t == 0 || t > n {
        return None;
    }
    let need: Vec<f64> = tenants
        .iter()
        .zip(rates.iter())
        .map(|(ts, &r)| {
            let per_slice = ServiceModel::new(ts.model.spec(), mig.gpcs_per_vgpu())
                .plateau_qps(ts.len_s);
            r / (per_slice * target_util).max(1e-9)
        })
        .collect();
    let mut alloc = vec![1usize; t];
    for _ in t..n {
        let mut best = 0usize;
        let mut best_deficit = f64::NEG_INFINITY;
        for (i, (&n_i, &a)) in need.iter().zip(alloc.iter()).enumerate() {
            let deficit = n_i - a as f64;
            if deficit > best_deficit {
                best_deficit = deficit;
                best = i;
            }
        }
        alloc[best] += 1;
    }
    Some(alloc)
}

/// Worst tenant's (predicted p95 / SLA) under `plan`, plus that p95 and
/// the tenant index.
pub fn worst_ratio(tenants: &[TenantSpec], rates: &[f64], plan: &Plan) -> (f64, f64, usize) {
    let mut ratio = 0.0;
    let mut p95 = 0.0;
    let mut idx = 0;
    for (i, (ts, (&r, &a))) in
        tenants.iter().zip(rates.iter().zip(plan.alloc.iter())).enumerate()
    {
        let p = predicted_p95_ms(ts, plan.mig, a, r);
        let q = p / ts.sla_ms.max(1e-9);
        if q > ratio {
            ratio = q;
            p95 = p;
            idx = i;
        }
    }
    (ratio, p95, idx)
}

/// Best (geometry, allocation) for the observed rates: evaluates every
/// MIG configuration with at least one slice per tenant and returns the
/// plan minimizing the worst tenant's predicted-p95/SLA ratio, plus that
/// ratio. Deterministic (fixed search order, strict improvement).
pub fn plan_for_rates(tenants: &[TenantSpec], rates: &[f64], target_util: f64) -> (Plan, f64) {
    assert!(!tenants.is_empty() && tenants.len() <= 7, "1..=7 tenants supported");
    let mut best: Option<(Plan, f64)> = None;
    for mig in MigConfig::ALL {
        let Some(alloc) = alloc_for_rates(tenants, rates, mig, target_util) else {
            continue;
        };
        let plan = Plan { mig, alloc };
        let (ratio, _, _) = worst_ratio(tenants, rates, &plan);
        let better = match &best {
            None => true,
            Some((_, b)) => ratio < *b,
        };
        if better {
            best = Some((plan, ratio));
        }
    }
    best.expect("Small7 admits up to 7 tenants")
}

/// The online decision gate. Feed it arrivals (`observe_arrival`) and call
/// [`ReconfigController::tick`] once per window; it returns `Some(plan)`
/// only when a repartition clears hysteresis, cooldown, and the amortized
/// cost-benefit check.
#[derive(Debug)]
pub struct ReconfigController {
    policy: ReconfigPolicy,
    tenants: Vec<TenantSpec>,
    watchers: Vec<RateWatcher>,
    plan: Plan,
    last_reconfig: Option<Nanos>,
    events: Vec<ReconfigEvent>,
}

impl ReconfigController {
    pub fn new(tenants: Vec<TenantSpec>, initial: Plan, policy: ReconfigPolicy) -> Self {
        assert_eq!(tenants.len(), initial.alloc.len(), "plan/tenant arity mismatch");
        assert!(!tenants.is_empty() && tenants.len() <= 7, "1..=7 tenants supported");
        let watchers = tenants.iter().map(|_| RateWatcher::new(policy.ewma_alpha)).collect();
        ReconfigController {
            policy,
            tenants,
            watchers,
            plan: initial,
            last_reconfig: None,
            events: Vec::new(),
        }
    }

    /// Decision cadence as virtual nanoseconds.
    pub fn window(&self) -> Nanos {
        secs(self.policy.window_s)
    }

    pub fn policy(&self) -> &ReconfigPolicy {
        &self.policy
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    pub fn events(&self) -> &[ReconfigEvent] {
        &self.events
    }

    /// Smoothed per-tenant rate estimates, queries/s.
    pub fn rates(&self) -> Vec<f64> {
        self.watchers.iter().map(RateWatcher::rate).collect()
    }

    /// Count one arrival for tenant `i` in the current window.
    pub fn observe_arrival(&mut self, i: usize) {
        self.watchers[i].observe();
    }

    /// Close the telemetry window without making a decision (used while a
    /// previous reconfiguration is still draining, or after the workload's
    /// final arrival).
    pub fn roll_only(&mut self, now: Nanos) {
        for w in &mut self.watchers {
            w.roll(now);
        }
    }

    /// Close the window at `now` and decide. `Some(plan)` commits the
    /// reconfiguration (the caller must then drain + apply it).
    pub fn tick(&mut self, now: Nanos) -> Option<Plan> {
        let rates: Vec<f64> = self.watchers.iter_mut().map(|w| w.roll(now)).collect();
        if let Some(t) = self.last_reconfig {
            if now < t.saturating_add(secs(self.policy.cooldown_s)) {
                return None;
            }
        }
        let (cur_ratio, cur_p95, worst_idx) = worst_ratio(&self.tenants, &rates, &self.plan);
        let (cand, cand_ratio) = plan_for_rates(&self.tenants, &rates, self.policy.target_util);
        if cand == self.plan {
            return None;
        }
        // Hysteresis deadband: ignore marginal improvements.
        if cand_ratio >= cur_ratio * (1.0 - self.policy.min_gain) {
            return None;
        }
        // Amortized reconfig-cost model: moving `moved` slices takes them
        // offline for ~repartition_s, displacing their share of the load
        // by ~repartition_s each (latency mass in query-seconds). The
        // switch must win that back, at the worst tenant's rate, within
        // one cooldown (the minimum commitment horizon).
        let (_, cand_p95, _) = worst_ratio(&self.tenants, &rates, &cand);
        let total_rate: f64 = rates.iter().sum();
        let moved = if cand.mig == self.plan.mig {
            let diff: usize = cand
                .alloc
                .iter()
                .zip(self.plan.alloc.iter())
                .map(|(&a, &b)| a.abs_diff(b))
                .sum();
            (diff / 2).max(1) as f64
        } else {
            self.plan.slices() as f64
        };
        let displaced_qps = total_rate * moved / self.plan.slices().max(1) as f64;
        let cost_qs = displaced_qps * self.policy.repartition_s * self.policy.repartition_s;
        let saved_qs =
            (cur_p95 - cand_p95) * 1e-3 * rates[worst_idx] * self.policy.cooldown_s;
        if saved_qs <= cost_qs {
            return None;
        }
        self.last_reconfig = Some(now);
        self.plan = cand.clone();
        self.events.push(ReconfigEvent {
            at: now,
            plan: cand.clone(),
            rates,
            predicted_gain_ms: cur_p95 - cand_p95,
        });
        Some(cand)
    }
}

// ---------------------------------------------------------------------------
// Cross-GPU planning (cluster scale)
// ---------------------------------------------------------------------------
//
// `server::cluster` runs one DES over N GPUs; a tenant's instances may be
// spread across several of them. Rebalancing then has TWO cost tiers:
// reassigning a slice between tenants already serving from the same GPU
// only repartitions that GPU (`repartition_s`), while granting a tenant a
// slice on a GPU it was not serving from requires shipping model weights
// and spinning up a fresh server there (`migration_s` ≫ `repartition_s`,
// the ParvaGPU/reconfigurable-scheduling cost asymmetry). The planner
// therefore prefers in-place reassignment and emits a migration only when
// the predicted amortized win clears the migration bar.

/// One planned slice reassignment on a cluster: on `gpu`, destroy one of
/// tenant `from`'s instances and create one for tenant `to`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SliceMove {
    pub gpu: usize,
    pub from: usize,
    pub to: usize,
    /// True when `to` had no instance on `gpu` before this move — a new
    /// residency that pays `migration_s` instead of `repartition_s`.
    pub migration: bool,
}

impl SliceMove {
    /// Outage this move charges the transferred capacity, seconds.
    pub fn outage_s(&self, policy: &ReconfigPolicy) -> f64 {
        if self.migration {
            policy.migration_s
        } else {
            policy.repartition_s
        }
    }
}

/// One committed cluster rebalance (timeline entry).
#[derive(Debug, Clone)]
pub struct ClusterReconfigEvent {
    pub at: Nanos,
    pub moves: Vec<SliceMove>,
    /// Smoothed per-tenant rates that justified the rebalance, queries/s.
    pub rates: Vec<f64>,
    /// Predicted worst-tenant p95 improvement, ms.
    pub predicted_gain_ms: f64,
}

impl ClusterReconfigEvent {
    pub fn migrations(&self) -> usize {
        self.moves.iter().filter(|m| m.migration).count()
    }
}

/// Slices a tenant needs for `rate_qps` at `target_util`, given its
/// instance profile. Never below 1 — a tenant keeps a foothold even when
/// idle, so it can serve the next request without a cold start. This is
/// THE sizing rule: the planner uses it online, and
/// `server::cluster::ClusterTenant::sized_for` uses it offline, so a
/// sized deployment starts exactly where the controller would put it.
pub fn slices_for_rate(spec: &TenantSpec, slice: Slice, rate_qps: f64, target_util: f64) -> usize {
    slices_for_rate_scaled(spec, slice, rate_qps, target_util, 1.0)
}

/// [`slices_for_rate`] with a curve-derived service-time scale: the
/// effective per-slice plateau shrinks by `service_scale`, so a
/// curve-aware planner provisions for the throughput the tenant will
/// actually see under its batch curve and expected neighbor contention,
/// not the flat model's optimistic one. `1.0` is bit-identical to the
/// unscaled rule.
pub fn slices_for_rate_scaled(
    spec: &TenantSpec,
    slice: Slice,
    rate_qps: f64,
    target_util: f64,
    service_scale: f64,
) -> usize {
    let per_slice =
        ServiceModel::new(spec.model.spec(), slice.gpcs).plateau_qps(spec.len_s) / service_scale;
    let need = rate_qps / (per_slice * target_util).max(1e-9);
    (need.ceil() as usize).max(1)
}

/// [`plan_cluster_moves_fleet`] over a homogeneous A100 inventory.
pub fn plan_cluster_moves(
    tenants: &[TenantSpec],
    slices: &[Slice],
    rates: &[f64],
    alloc: &[Vec<usize>],
    policy: &ReconfigPolicy,
) -> Vec<SliceMove> {
    let fleet = vec![GpuClass::A100; alloc.len()];
    plan_cluster_moves_fleet(tenants, slices, rates, alloc, &fleet, policy)
}

/// Plan slice moves for observed rates over a cluster allocation
/// (`alloc[gpu][tenant]` = instance count; `fleet[gpu]` gives each GPU's
/// class capacity — heterogeneous inventories score every GPU against
/// its own GPC/memory budget, so a gainer's profile that exceeds a class
/// is simply never planned onto it). Greedy and deterministic: the
/// worst-deficit tenant is served first, from the biggest-surplus donor,
/// preferring GPUs where the gainer is already resident (in-place). A
/// migration (new residency) is emitted only when no in-place option
/// exists AND the gainer's predicted p95 gain from one more slice
/// amortizes `migration_s` within one cooldown. Donors never drop below
/// their own need (min 1 slice).
pub fn plan_cluster_moves_fleet(
    tenants: &[TenantSpec],
    slices: &[Slice],
    rates: &[f64],
    alloc: &[Vec<usize>],
    fleet: &[GpuClass],
    policy: &ReconfigPolicy,
) -> Vec<SliceMove> {
    let ones = vec![1.0; tenants.len()];
    plan_cluster_moves_fleet_scaled(tenants, slices, rates, alloc, fleet, policy, &ones)
}

/// [`plan_cluster_moves_fleet`] with per-tenant curve-derived service-time
/// scales (`scales[i] >= 1.0` inflates tenant `i`'s sizing need and
/// predicted p95). All-ones is bit-identical to the unscaled planner.
#[allow(clippy::too_many_arguments)]
pub fn plan_cluster_moves_fleet_scaled(
    tenants: &[TenantSpec],
    slices: &[Slice],
    rates: &[f64],
    alloc: &[Vec<usize>],
    fleet: &[GpuClass],
    policy: &ReconfigPolicy,
    scales: &[f64],
) -> Vec<SliceMove> {
    let t = tenants.len();
    assert!(t > 0 && slices.len() == t && rates.len() == t, "tenant arity mismatch");
    assert_eq!(scales.len(), t, "scales arity mismatch");
    let n_gpus = alloc.len();
    assert_eq!(fleet.len(), n_gpus, "fleet/alloc arity mismatch");
    let mut state: Vec<Vec<usize>> = alloc.to_vec();
    for g in &state {
        assert_eq!(g.len(), t, "alloc arity mismatch");
    }

    let need: Vec<usize> = (0..t)
        .map(|i| {
            slices_for_rate_scaled(&tenants[i], slices[i], rates[i], policy.target_util, scales[i])
        })
        .collect();
    let mut have: Vec<usize> = (0..t)
        .map(|i| state.iter().map(|g| g[i]).sum())
        .collect();
    let mut gpc_free: Vec<usize> = (0..n_gpus)
        .map(|g| {
            fleet[g].gpcs.saturating_sub((0..t).map(|i| state[g][i] * slices[i].gpcs).sum())
        })
        .collect();
    let mut mem_free: Vec<usize> = (0..n_gpus)
        .map(|g| {
            fleet[g].mem_gb.saturating_sub((0..t).map(|i| state[g][i] * slices[i].mem_gb).sum())
        })
        .collect();

    // Freeing one of `d`'s slices on `g` leaves room for one of `i`'s?
    // (`supports` is implied by the free-capacity arithmetic — freed
    // capacity can never exceed the class — but stays explicit so the
    // per-class feasibility rule is visible at the decision point.)
    let fits = |gpc_free: &[usize], mem_free: &[usize], g: usize, d: usize, i: usize| {
        fleet[g].supports(&slices[i])
            && gpc_free[g] + slices[d].gpcs >= slices[i].gpcs
            && mem_free[g] + slices[d].mem_gb >= slices[i].mem_gb
    };

    let mut moves = Vec::new();
    let mut skip = vec![false; t];
    loop {
        // Worst-deficit gainer not yet marked unservable this round.
        let gainer = (0..t)
            .filter(|&i| !skip[i] && have[i] < need[i])
            .max_by_key(|&i| (need[i] - have[i], usize::MAX - i));
        let Some(gi) = gainer else { break };

        // Donors by surplus (desc), index (asc) — deterministic.
        let mut donors: Vec<usize> =
            (0..t).filter(|&d| d != gi && have[d] > need[d]).collect();
        donors.sort_by_key(|&d| (usize::MAX - (have[d] - need[d]), d));

        // Pass 1: in-place — a donor slice on a GPU the gainer already
        // serves from.
        let mut chosen: Option<(usize, usize, bool)> = None; // (gpu, donor, migration)
        'inplace: for &d in &donors {
            for g in 0..n_gpus {
                if state[g][d] > 0
                    && state[g][gi] > 0
                    && fits(&gpc_free, &mem_free, g, d, gi)
                {
                    chosen = Some((g, d, false));
                    break 'inplace;
                }
            }
        }
        // Pass 2: migration — each candidate donor is gated by the
        // amortized-cost bar (the predicted p95 gain of the gainer's
        // extra slice must win back the displaced load within one
        // cooldown). A heavily loaded donor failing the bar does not end
        // the search: a lighter-loaded donor may still amortize the move.
        if chosen.is_none() {
            let p95_at = |n: usize| {
                predicted_p95_ms_gpcs_scaled(
                    &tenants[gi],
                    slices[gi].gpcs,
                    n,
                    rates[gi],
                    scales[gi],
                )
            };
            let gain_ms = p95_at(have[gi]) - p95_at(have[gi] + 1);
            let saved_qs = gain_ms * 1e-3 * rates[gi] * policy.cooldown_s;
            'migrate: for &d in &donors {
                for g in 0..n_gpus {
                    if state[g][d] > 0
                        && state[g][gi] == 0
                        && fits(&gpc_free, &mem_free, g, d, gi)
                    {
                        // Load displaced by the move: the donor slice's
                        // share goes offline, and the gainer's share of
                        // the new slice arrives `migration_s` late.
                        let displaced_qps = rates[d] / have[d].max(1) as f64
                            + rates[gi] / (have[gi] + 1) as f64;
                        let cost_qs = displaced_qps * policy.migration_s * policy.migration_s;
                        if saved_qs > cost_qs {
                            chosen = Some((g, d, true));
                            break 'migrate;
                        }
                        // This donor can't amortize the move; try the
                        // next one (lowest-g candidate per donor).
                        continue 'migrate;
                    }
                }
            }
        }

        match chosen {
            None => skip[gi] = true,
            Some((g, d, migration)) => {
                state[g][d] -= 1;
                state[g][gi] += 1;
                have[d] -= 1;
                have[gi] += 1;
                gpc_free[g] = gpc_free[g] + slices[d].gpcs - slices[gi].gpcs;
                mem_free[g] = mem_free[g] + slices[d].mem_gb - slices[gi].mem_gb;
                moves.push(SliceMove { gpu: g, from: d, to: gi, migration });
            }
        }
    }
    moves
}

/// The shared plan-validity checker: replay `moves` over `alloc` and
/// prove the plan legal end to end. Every planner's output passes
/// through here before [`ClusterReconfigController::tick`] commits it,
/// and the property suites assert against the same rules instead of
/// carrying their own copies. On success the post-plan allocation is
/// returned; on failure the message names the first violated rule.
///
/// The rules:
/// * arity — `fleet`, `failed` and `alloc` agree on the GPU count and
///   every alloc row covers every tenant;
/// * per-class capacity — each GPU's placed GPCs/memory stay within its
///   class **before the plan, after every move, and after the plan**
///   (moves destroy the donor instance before creating the gainer's);
/// * class support — no profile a class cannot host (a `7g.40gb` never
///   lands on a 4-GPC class) and no illegal profile anywhere;
/// * atomic move legality — each move names a resident donor instance,
///   is not a self-move, and its `migration` flag is truthful at the
///   point it applies;
/// * failed GPUs — no instance rests on a failed GPU and no move
///   touches one;
/// * no starvation — a tenant serving before the plan still serves
///   after it.
pub fn validate_plan(
    slices: &[Slice],
    fleet: &[GpuClass],
    failed: &[bool],
    alloc: &[Vec<usize>],
    moves: &[SliceMove],
) -> Result<Vec<Vec<usize>>, String> {
    let t = slices.len();
    let n = fleet.len();
    if alloc.len() != n || failed.len() != n {
        return Err(format!(
            "arity mismatch: {n} GPUs in fleet, {} alloc rows, {} failed flags",
            alloc.len(),
            failed.len()
        ));
    }
    for (g, row) in alloc.iter().enumerate() {
        if row.len() != t {
            return Err(format!("gpu{g} alloc row covers {} of {t} tenants", row.len()));
        }
    }
    let check_state = |state: &[Vec<usize>], when: &str| -> Result<(), String> {
        for g in 0..n {
            let mut gpcs = 0;
            let mut mem = 0;
            for i in 0..t {
                let c = state[g][i];
                if c == 0 {
                    continue;
                }
                if failed[g] {
                    return Err(format!(
                        "tenant {i} holds {c} instance(s) on failed gpu{g} {when}"
                    ));
                }
                if !slices[i].is_legal() {
                    return Err(format!(
                        "tenant {i} uses illegal profile {}g.{}gb",
                        slices[i].gpcs, slices[i].mem_gb
                    ));
                }
                if !fleet[g].supports(&slices[i]) {
                    return Err(format!(
                        "tenant {i}'s {}g.{}gb cannot land on gpu{g} ({}: {} GPCs)",
                        slices[i].gpcs, slices[i].mem_gb, fleet[g].name, fleet[g].gpcs
                    ));
                }
                gpcs += c * slices[i].gpcs;
                mem += c * slices[i].mem_gb;
            }
            if gpcs > fleet[g].gpcs || mem > fleet[g].mem_gb {
                return Err(format!(
                    "gpu{g} ({}) over capacity {when}: {gpcs}/{} GPCs, {mem}/{} GB",
                    fleet[g].name, fleet[g].gpcs, fleet[g].mem_gb
                ));
            }
        }
        Ok(())
    };
    check_state(alloc, "before the plan")?;
    let mut state = alloc.to_vec();
    for (k, m) in moves.iter().enumerate() {
        if m.gpu >= n || m.from >= t || m.to >= t {
            return Err(format!("move {k} is out of range: {m:?}"));
        }
        if m.from == m.to {
            return Err(format!("move {k} is a self-move: {m:?}"));
        }
        if failed[m.gpu] {
            return Err(format!("move {k} touches failed gpu{}: {m:?}", m.gpu));
        }
        if state[m.gpu][m.from] == 0 {
            return Err(format!("move {k} donates a non-resident instance: {m:?}"));
        }
        if (state[m.gpu][m.to] == 0) != m.migration {
            return Err(format!(
                "move {k} mislabels residency (migration flag untruthful): {m:?}"
            ));
        }
        state[m.gpu][m.from] -= 1;
        state[m.gpu][m.to] += 1;
        // Destroy-then-create: the intermediate state after THIS move
        // must already fit — a plan may not borrow capacity from moves
        // that have not happened yet.
        check_state(&state, &format!("after move {k}"))?;
    }
    for i in 0..t {
        let before: usize = alloc.iter().map(|g| g[i]).sum();
        let after: usize = state.iter().map(|g| g[i]).sum();
        if before > 0 && after == 0 {
            return Err(format!("tenant {i} starved: {before} instance(s) before, 0 after"));
        }
    }
    Ok(state)
}

/// One cross-GPU slice relocation planned by consolidation: tenant
/// `tenant` gives up an instance on `from_gpu` and receives one on
/// `to_gpu` (a migration-cost move — weights ship, the server restarts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Relocation {
    pub tenant: usize,
    pub from_gpu: usize,
    pub to_gpu: usize,
}

/// A committed energy decision
/// ([`ClusterReconfigController::tick_consolidation`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConsolidationAction {
    /// Drain `gpu` so it can be powered off: `retire` destroys surplus
    /// replicas (scale-in; `(gpu, tenant)` per instance, victim first),
    /// `relocate` migrates the victim's remaining residents into free
    /// capacity elsewhere. Every move pays the usual drain + outage in
    /// the DES; the GPU powers off once its last mover drains.
    PowerDown { gpu: usize, retire: Vec<(usize, usize)>, relocate: Vec<Relocation> },
    /// Wake a parked GPU for under-provisioned demand: `grants` creates
    /// `(tenant, count)` fresh instances on `gpu`, each paying the
    /// migration (spin-up) outage before it serves.
    PowerUp { gpu: usize, grants: Vec<(usize, usize)> },
}

/// Timeline entry for one committed consolidation decision.
#[derive(Debug, Clone)]
pub struct ConsolidationEvent {
    pub at: Nanos,
    pub gpu: usize,
    /// True for a power-down, false for a wake.
    pub powered_down: bool,
    /// Surplus replicas destroyed (power-down only).
    pub retired: usize,
    /// Instances migrated off the victim / granted on the woken GPU.
    pub moved: usize,
    /// Smoothed per-tenant rates behind the decision, queries/s.
    pub rates: Vec<f64>,
}

/// Cluster-scale decision gate: the [`ReconfigController`] pattern over a
/// multi-GPU allocation. Feed it arrivals, call `tick` once per window;
/// it returns the committed move list only when the rebalance clears
/// hysteresis, cooldown, and the amortized cost model (with migrations
/// additionally gated per-move inside [`plan_cluster_moves`]). With
/// [`ReconfigPolicy::consolidate`] set, a second per-window pass
/// ([`Self::tick_consolidation`]) makes the energy decision.
#[derive(Debug)]
pub struct ClusterReconfigController {
    policy: ReconfigPolicy,
    tenants: Vec<TenantSpec>,
    slices: Vec<Slice>,
    fleet: Vec<GpuClass>,
    watchers: Vec<RateWatcher>,
    alloc: Vec<Vec<usize>>,
    last_reconfig: Option<Nanos>,
    events: Vec<ClusterReconfigEvent>,
    /// Per-GPU powered-down flags (consolidation victims).
    powered_down: Vec<bool>,
    /// Per-GPU failed flags (fault injection): a failed GPU is invisible
    /// to admission, planning, and the power paths until repaired.
    failed: Vec<bool>,
    /// Consecutive low-load windows seen (consolidation hysteresis).
    low_windows: usize,
    /// Rates from the latest [`Self::tick`] roll, for the consolidation
    /// pass of the same window.
    last_rates: Vec<f64>,
    consolidation_events: Vec<ConsolidationEvent>,
    /// Per-tenant curve-derived service-time scales the planner applies
    /// to sizing and p95 prediction (`>= 1.0`; all-ones = flat model).
    service_scales: Vec<f64>,
}

impl ClusterReconfigController {
    /// Homogeneous-A100 constructor ([`Self::with_fleet`] with every GPU
    /// an [`GpuClass::A100`]).
    pub fn new(
        tenants: Vec<TenantSpec>,
        slices: Vec<Slice>,
        initial_alloc: Vec<Vec<usize>>,
        policy: ReconfigPolicy,
    ) -> Self {
        let fleet = vec![GpuClass::A100; initial_alloc.len()];
        Self::with_fleet(tenants, slices, fleet, initial_alloc, policy)
    }

    /// Controller over a (possibly heterogeneous) fleet: `fleet[gpu]`
    /// gives each GPU's class, and every planning decision scores free
    /// capacity against that class.
    pub fn with_fleet(
        tenants: Vec<TenantSpec>,
        slices: Vec<Slice>,
        fleet: Vec<GpuClass>,
        initial_alloc: Vec<Vec<usize>>,
        policy: ReconfigPolicy,
    ) -> Self {
        assert_eq!(tenants.len(), slices.len(), "tenant/slice arity mismatch");
        assert_eq!(fleet.len(), initial_alloc.len(), "fleet/alloc arity mismatch");
        for g in &initial_alloc {
            assert_eq!(g.len(), tenants.len(), "alloc/tenant arity mismatch");
        }
        let watchers = tenants.iter().map(|_| RateWatcher::new(policy.ewma_alpha)).collect();
        let n_gpus = initial_alloc.len();
        let n_tenants = tenants.len();
        ClusterReconfigController {
            policy,
            tenants,
            slices,
            fleet,
            watchers,
            alloc: initial_alloc,
            last_reconfig: None,
            events: Vec::new(),
            powered_down: vec![false; n_gpus],
            failed: vec![false; n_gpus],
            low_windows: 0,
            last_rates: Vec::new(),
            consolidation_events: Vec::new(),
            service_scales: vec![1.0; n_tenants],
        }
    }

    /// Install per-tenant curve-derived service-time scales (see
    /// [`crate::config::CurvesConfig`]): every sizing (`slices_for_rate`)
    /// and prediction (`predicted_p95_ms_gpcs`) the controller makes is
    /// then curve-aware. All-ones (the default) is bit-identical to the
    /// flat controller.
    pub fn with_service_scales(mut self, scales: Vec<f64>) -> Self {
        assert_eq!(scales.len(), self.tenants.len(), "scales/tenant arity mismatch");
        assert!(scales.iter().all(|s| s.is_finite() && *s > 0.0), "scales must be positive");
        self.service_scales = scales;
        self
    }

    /// The installed per-tenant service-time scales.
    pub fn service_scales(&self) -> &[f64] {
        &self.service_scales
    }

    /// Per-GPU classes the controller plans against.
    pub fn fleet(&self) -> &[GpuClass] {
        &self.fleet
    }

    /// Try to admit one pending (previously rejected) instance of tenant
    /// `ti`'s profile into currently-free capacity: the first GPU whose
    /// class supports the profile and whose free GPCs/memory (given the
    /// live alloc mirror) fit it. Updates the mirror and returns the GPU
    /// index, or `None` while no capacity has freed up. This is the
    /// admission-control re-pack hook: the cluster DES offers its pending
    /// ask queue here every telemetry window, so capacity released by
    /// rebalances (drain/outage moves during diurnal troughs) is handed
    /// to deferred demand instead of sitting stranded.
    pub fn try_admit(&mut self, ti: usize) -> Option<usize> {
        let t = self.tenants.len();
        let s = self.slices[ti];
        for (g, class) in self.fleet.iter().enumerate() {
            if self.failed[g] || !class.supports(&s) {
                continue;
            }
            let gpcs_used: usize = (0..t).map(|i| self.alloc[g][i] * self.slices[i].gpcs).sum();
            let mem_used: usize = (0..t).map(|i| self.alloc[g][i] * self.slices[i].mem_gb).sum();
            if class.gpcs - gpcs_used.min(class.gpcs) >= s.gpcs
                && class.mem_gb - mem_used.min(class.mem_gb) >= s.mem_gb
            {
                self.alloc[g][ti] += 1;
                // Admitting into a consolidation-parked GPU wakes it
                // (the caller pays the spin-up as a migration outage).
                self.powered_down[g] = false;
                return Some(g);
            }
        }
        None
    }

    /// Decision cadence as virtual nanoseconds.
    pub fn window(&self) -> Nanos {
        secs(self.policy.window_s)
    }

    pub fn policy(&self) -> &ReconfigPolicy {
        &self.policy
    }

    /// Swap the planning algorithm mid-run. Only the planner changes:
    /// telemetry, cooldown state and the commit gates carry over, so the
    /// no-thrash contract (events ≥ `cooldown_s` apart) is unaffected.
    pub fn set_planner(&mut self, kind: PlannerKind) {
        self.policy.planner = kind;
    }

    /// Current `alloc[gpu][tenant]` mirror.
    pub fn alloc(&self) -> &[Vec<usize>] {
        &self.alloc
    }

    pub fn events(&self) -> &[ClusterReconfigEvent] {
        &self.events
    }

    /// Committed migrations (new residencies) so far.
    pub fn migrations(&self) -> u64 {
        self.events.iter().map(|e| e.migrations() as u64).sum()
    }

    /// Count one arrival for tenant `i` in the current window.
    pub fn observe_arrival(&mut self, i: usize) {
        self.watchers[i].observe();
    }

    /// Close the telemetry window without deciding (workload tail).
    pub fn roll_only(&mut self, now: Nanos) {
        self.last_rates = self.watchers.iter_mut().map(|w| w.roll(now)).collect();
    }

    /// Close the window at `now` and decide. `Some(moves)` commits the
    /// rebalance (the caller must drain + apply each move).
    pub fn tick(&mut self, now: Nanos) -> Option<Vec<SliceMove>> {
        let rates: Vec<f64> = self.watchers.iter_mut().map(|w| w.roll(now)).collect();
        self.last_rates = rates.clone();
        if let Some(t) = self.last_reconfig {
            if now < t.saturating_add(secs(self.policy.cooldown_s)) {
                return None;
            }
        }
        // A failed GPU contributes no capacity: mask its class to zero so
        // the planner can neither migrate into it nor count it as free
        // (its alloc row was zeroed when the failure was detected).
        let fleet: Vec<GpuClass> = self
            .fleet
            .iter()
            .zip(&self.failed)
            .map(|(&c, &down)| if down { GpuClass { gpcs: 0, mem_gb: 0, ..c } } else { c })
            .collect();
        let inst = PlanInstance {
            tenants: &self.tenants,
            slices: &self.slices,
            rates: &rates,
            alloc: &self.alloc,
            fleet: &fleet,
            policy: &self.policy,
            scales: &self.service_scales,
        };
        let moves = self.policy.planner.planner(&self.policy).plan(&inst);
        if moves.is_empty() {
            return None;
        }
        // Defense in depth: any planner's plan must replay cleanly. An
        // invalid plan is a planner bug — fatal under test, refused (not
        // committed) in release builds.
        if let Err(e) = validate_plan(&self.slices, &self.fleet, &self.failed, &self.alloc, &moves)
        {
            let who = self.policy.planner.label();
            debug_assert!(false, "planner '{who}' emitted an invalid plan: {e}");
            return None;
        }
        let t = self.tenants.len();
        let have: Vec<usize> =
            (0..t).map(|i| self.alloc.iter().map(|g| g[i]).sum()).collect();
        let mut have_after = have.clone();
        for m in &moves {
            have_after[m.from] -= 1;
            have_after[m.to] += 1;
        }
        // Gate on the tenants the moves actually touch. Scoring the whole
        // fleet would let one unservable tenant (e.g. a rejected ask no
        // move can fit) dominate worst-ratio before AND after, blocking
        // every legitimate rebalance among the others forever.
        let touched: Vec<usize> = (0..t).filter(|&i| have_after[i] != have[i]).collect();
        let p95_of = |i: usize, n: usize| {
            predicted_p95_ms_gpcs_scaled(
                &self.tenants[i],
                self.slices[i].gpcs,
                n,
                rates[i],
                self.service_scales[i],
            )
        };
        let worst_over = |haves: &[usize]| -> (f64, f64) {
            let mut ratio = 0.0;
            let mut p95 = 0.0;
            for &i in &touched {
                let p = p95_of(i, haves[i]);
                let q = p / self.tenants[i].sla_ms.max(1e-9);
                if q > ratio {
                    ratio = q;
                    p95 = p;
                }
            }
            (ratio, p95)
        };
        let (cur_ratio, cur_p95) = worst_over(&have);
        let (cand_ratio, cand_p95) = worst_over(&have_after);
        // Hysteresis deadband: ignore marginal improvements.
        if cand_ratio >= cur_ratio * (1.0 - self.policy.min_gain) {
            return None;
        }
        // Amortized cost across the whole move list: each move takes the
        // donor slice's share of load offline for its outage, and delays
        // the gainer's new capacity by the same outage.
        let cost_qs: f64 = moves
            .iter()
            .map(|m| {
                let outage = m.outage_s(&self.policy);
                let displaced = rates[m.from] / have[m.from].max(1) as f64
                    + rates[m.to] / (have[m.to] + 1) as f64;
                displaced * outage * outage
            })
            .sum();
        // Net latency mass saved across the touched tenants (donors'
        // small degradation subtracts) — summing per tenant keeps the
        // gate correct when the worst-by-ratio identity changes across
        // the move under mixed per-tenant SLAs.
        let saved_qs: f64 = touched
            .iter()
            .map(|&i| {
                (p95_of(i, have[i]) - p95_of(i, have_after[i]))
                    * 1e-3
                    * rates[i]
                    * self.policy.cooldown_s
            })
            .sum();
        if saved_qs <= cost_qs {
            return None;
        }
        for m in &moves {
            self.alloc[m.gpu][m.from] -= 1;
            self.alloc[m.gpu][m.to] += 1;
        }
        self.last_reconfig = Some(now);
        self.events.push(ClusterReconfigEvent {
            at: now,
            moves: moves.clone(),
            rates,
            predicted_gain_ms: cur_p95 - cand_p95,
        });
        Some(moves)
    }

    /// Per-GPU powered-down flags (true = parked by consolidation).
    pub fn powered_down(&self) -> &[bool] {
        &self.powered_down
    }

    /// Committed power-downs so far.
    pub fn consolidations(&self) -> u64 {
        self.consolidation_events.iter().filter(|e| e.powered_down).count() as u64
    }

    pub fn consolidation_events(&self) -> &[ConsolidationEvent] {
        &self.consolidation_events
    }

    /// Per-GPU failed flags (true = crashed and not yet repaired).
    pub fn gpu_failed(&self) -> &[bool] {
        &self.failed
    }

    /// A detected GPU crash: the GPU's capacity is gone. Marks it failed
    /// (so `try_admit`, the move planner, and both power paths skip it),
    /// zeroes its alloc-mirror row, and returns the displaced
    /// `(tenant, count)` holdings so the caller can re-offer them as
    /// pending asks — the failover re-pack rides the same admission seam
    /// rebalances already use.
    pub fn fail_gpu(&mut self, g: usize) -> Vec<(usize, usize)> {
        self.failed[g] = true;
        let mut displaced = Vec::new();
        for (ti, n) in self.alloc[g].iter_mut().enumerate() {
            if *n > 0 {
                displaced.push((ti, *n));
                *n = 0;
            }
        }
        displaced
    }

    /// A repaired GPU rejoins the pool empty; pending asks re-admit
    /// through [`Self::try_admit`] at the next telemetry window.
    pub fn restore_gpu(&mut self, g: usize) {
        self.failed[g] = false;
    }

    /// A single-slice failure on `g` destroyed one of `ti`'s instances:
    /// keep the alloc mirror truthful so planning stays honest.
    pub fn note_slice_lost(&mut self, g: usize, ti: usize) {
        self.alloc[g][ti] = self.alloc[g][ti].saturating_sub(1);
    }

    /// The failed slice on `g` came back for tenant `ti`.
    pub fn note_slice_restored(&mut self, g: usize, ti: usize) {
        self.alloc[g][ti] += 1;
    }

    /// Roll back the rebalance [`Self::tick`] just committed — a
    /// repartition abort mid-drain (fault injection) or a donor that
    /// crashed between plan and apply. The alloc mirror reverts move by
    /// move and the event is popped (aborted rebalances don't count as
    /// reconfigurations), but `last_reconfig` stands: the failed attempt
    /// still burns the cooldown, so an abort can't cause thrash.
    pub fn abort_last(&mut self) -> Option<ClusterReconfigEvent> {
        let ev = self.events.pop()?;
        for m in ev.moves.iter().rev() {
            self.alloc[m.gpu][m.from] += 1;
            self.alloc[m.gpu][m.to] -= 1;
        }
        Some(ev)
    }

    /// GPCs of `g` currently allocated to instances.
    fn used_gpcs(&self, g: usize) -> usize {
        (0..self.tenants.len()).map(|i| self.alloc[g][i] * self.slices[i].gpcs).sum()
    }

    /// The energy decision for the window [`Self::tick`] just closed —
    /// call it right after `tick` (it reuses that roll's rates; a tick
    /// that committed moves started the shared cooldown, so the two
    /// passes can never fight within a window).
    ///
    /// * **Power-down** — after `consolidate_windows` consecutive
    ///   windows with fleet slice-utilization below `consolidate_util`,
    ///   shrink every tenant to a `rate / consolidate_util` provision
    ///   (surplus replicas retire) and migrate the lightest GPU's
    ///   remaining residents away so it can park. Tenants always keep at
    ///   least one instance.
    /// * **Power-up** — when demand outgrows the powered-up provision
    ///   (some tenant's needed slice count exceeds its holdings), the
    ///   lowest-index parked GPU that fits the starved profiles is woken
    ///   with fresh grants.
    pub fn tick_consolidation(&mut self, now: Nanos) -> Option<ConsolidationAction> {
        if !self.policy.consolidate || self.last_rates.len() != self.tenants.len() {
            return None;
        }
        let t = self.tenants.len();
        let rates = self.last_rates.clone();
        let need: Vec<usize> = (0..t)
            .map(|i| {
                slices_for_rate_scaled(
                    &self.tenants[i],
                    self.slices[i],
                    rates[i],
                    self.policy.target_util,
                    self.service_scales[i],
                )
            })
            .collect();
        let have: Vec<usize> =
            (0..t).map(|i| self.alloc.iter().map(|g| g[i]).sum()).collect();
        let cooled = match self.last_reconfig {
            None => true,
            Some(at) => now >= at.saturating_add(secs(self.policy.cooldown_s)),
        };

        // Scale-out: demand the powered-up provision cannot cover wakes
        // a parked GPU (the rate-driven planner already had its chance
        // this window — it can only shuffle existing instances).
        let deficit: Vec<usize> = (0..t).map(|i| need[i].saturating_sub(have[i])).collect();
        if deficit.iter().sum::<usize>() > 0 {
            self.low_windows = 0;
            if !cooled {
                return None;
            }
            return self.plan_power_up(now, &rates, &deficit);
        }

        // Scale-in hysteresis: fleet slice-utilization must stay low for
        // `consolidate_windows` consecutive windows.
        let total_have: usize = have.iter().sum();
        let util = need.iter().sum::<usize>() as f64 / total_have.max(1) as f64;
        if util >= self.policy.consolidate_util {
            self.low_windows = 0;
            return None;
        }
        self.low_windows += 1;
        if self.low_windows < self.policy.consolidate_windows || !cooled {
            return None;
        }
        self.plan_power_down(now, &rates, &have)
    }

    fn plan_power_up(
        &mut self,
        now: Nanos,
        rates: &[f64],
        deficit: &[usize],
    ) -> Option<ConsolidationAction> {
        let t = self.tenants.len();
        // Largest deficit first (ties to the lowest tenant index).
        let mut order: Vec<usize> = (0..t).filter(|&i| deficit[i] > 0).collect();
        order.sort_by_key(|&i| (usize::MAX - deficit[i], i));
        // Lowest-index parked GPU whose class fits at least one starved
        // profile — a parked GPU that fits nothing (e.g. an A30 while
        // only 7g tenants starve) must not block waking one that does.
        let parked: Vec<usize> = (0..self.fleet.len())
            .filter(|&g| self.powered_down[g] && !self.failed[g])
            .collect();
        for gpu in parked {
            let class = self.fleet[gpu];
            let mut free_gpc = class.gpcs.saturating_sub(self.used_gpcs(gpu));
            let mut free_mem = class.mem_gb.saturating_sub(
                (0..t).map(|i| self.alloc[gpu][i] * self.slices[i].mem_gb).sum(),
            );
            let mut grants: Vec<(usize, usize)> = Vec::new();
            for &i in &order {
                let s = self.slices[i];
                if !class.supports(&s) {
                    continue;
                }
                let mut granted = 0;
                while granted < deficit[i] && free_gpc >= s.gpcs && free_mem >= s.mem_gb {
                    free_gpc -= s.gpcs;
                    free_mem -= s.mem_gb;
                    granted += 1;
                }
                if granted > 0 {
                    grants.push((i, granted));
                }
            }
            if grants.is_empty() {
                continue;
            }
            for &(i, n) in &grants {
                self.alloc[gpu][i] += n;
            }
            self.powered_down[gpu] = false;
            self.last_reconfig = Some(now);
            self.consolidation_events.push(ConsolidationEvent {
                at: now,
                gpu,
                powered_down: false,
                retired: 0,
                moved: grants.iter().map(|&(_, n)| n).sum(),
                rates: rates.to_vec(),
            });
            return Some(ConsolidationAction::PowerUp { gpu, grants });
        }
        None
    }

    fn plan_power_down(
        &mut self,
        now: Nanos,
        rates: &[f64],
        have: &[usize],
    ) -> Option<ConsolidationAction> {
        let t = self.tenants.len();
        let n_gpus = self.fleet.len();
        let up: Vec<usize> =
            (0..n_gpus).filter(|&g| !self.powered_down[g] && !self.failed[g]).collect();
        if up.len() < 2 {
            return None;
        }
        // Provision each tenant for rate / consolidate_util — the
        // headroom that keeps the post-consolidation fleet comfortable
        // if demand doubles before the wake path reacts.
        let keep: Vec<usize> = (0..t)
            .map(|i| {
                let provisioned_rate = rates[i] / self.policy.consolidate_util.max(1e-3);
                slices_for_rate_scaled(
                    &self.tenants[i],
                    self.slices[i],
                    provisioned_rate,
                    self.policy.target_util,
                    self.service_scales[i],
                )
                .min(have[i])
                .max(1)
            })
            .collect();
        // Candidate victims: lightest first; ties prefer the highest
        // index so low-index GPUs stay the stable residents.
        let mut cands = up.clone();
        cands.sort_by_key(|&g| (self.used_gpcs(g), usize::MAX - g));
        'victims: for &victim in &cands {
            let mut state = self.alloc.clone();
            // saturating: a zero-holding tenant (possible only through a
            // rejected ask) keeps nothing rather than underflowing.
            let mut surplus: Vec<usize> =
                (0..t).map(|i| have[i].saturating_sub(keep[i])).collect();
            let mut retire: Vec<(usize, usize)> = Vec::new();
            // Retire surplus replicas, victim residents first, so the
            // scale-in itself empties as much of the victim (and frees
            // as much room elsewhere) as possible.
            let retire_on = |g: usize,
                             state: &mut Vec<Vec<usize>>,
                             surplus: &mut Vec<usize>,
                             retire: &mut Vec<(usize, usize)>| {
                for i in 0..t {
                    let r = state[g][i].min(surplus[i]);
                    for _ in 0..r {
                        retire.push((g, i));
                    }
                    state[g][i] -= r;
                    surplus[i] -= r;
                }
            };
            retire_on(victim, &mut state, &mut surplus, &mut retire);
            for &g in &up {
                if g != victim {
                    retire_on(g, &mut state, &mut surplus, &mut retire);
                }
            }
            // Relocate the victim's remaining residents into free
            // capacity on the surviving GPUs (class-checked).
            let mut free_gpc: Vec<usize> = (0..n_gpus)
                .map(|g| {
                    self.fleet[g].gpcs.saturating_sub(
                        (0..t).map(|i| state[g][i] * self.slices[i].gpcs).sum(),
                    )
                })
                .collect();
            let mut free_mem: Vec<usize> = (0..n_gpus)
                .map(|g| {
                    self.fleet[g].mem_gb.saturating_sub(
                        (0..t).map(|i| state[g][i] * self.slices[i].mem_gb).sum(),
                    )
                })
                .collect();
            let mut relocate: Vec<Relocation> = Vec::new();
            for i in 0..t {
                for _ in 0..state[victim][i] {
                    let s = self.slices[i];
                    let target = up.iter().copied().find(|&g| {
                        g != victim
                            && self.fleet[g].supports(&s)
                            && free_gpc[g] >= s.gpcs
                            && free_mem[g] >= s.mem_gb
                    });
                    match target {
                        None => continue 'victims,
                        Some(g) => {
                            free_gpc[g] -= s.gpcs;
                            free_mem[g] -= s.mem_gb;
                            relocate.push(Relocation { tenant: i, from_gpu: victim, to_gpu: g });
                        }
                    }
                }
            }
            // Commit.
            for &(g, i) in &retire {
                self.alloc[g][i] -= 1;
            }
            for r in &relocate {
                self.alloc[r.from_gpu][r.tenant] -= 1;
                self.alloc[r.to_gpu][r.tenant] += 1;
            }
            self.powered_down[victim] = true;
            self.last_reconfig = Some(now);
            self.low_windows = 0;
            self.consolidation_events.push(ConsolidationEvent {
                at: now,
                gpu: victim,
                powered_down: true,
                retired: retire.len(),
                moved: relocate.len(),
                rates: rates.to_vec(),
            });
            return Some(ConsolidationAction::PowerDown { gpu: victim, retire, relocate });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::millis;

    fn swin(sla_ms: f64) -> TenantSpec {
        TenantSpec { model: ModelId::SwinTransformer, sla_ms, len_s: 0.0 }
    }

    #[test]
    fn watcher_estimates_rate_and_smooths() {
        let mut w = RateWatcher::new(0.5);
        for _ in 0..100 {
            w.observe();
        }
        let r1 = w.roll(secs(1.0));
        assert!((r1 - 100.0).abs() < 1e-9, "{r1}");
        // Next window empty: EWMA halves rather than dropping to zero.
        let r2 = w.roll(secs(2.0));
        assert!((r2 - 50.0).abs() < 1e-9, "{r2}");
    }

    #[test]
    fn low_rate_prediction_includes_batching_deadline() {
        // A lone request waits the full Time_queue before executing.
        let ts = swin(50.0);
        let p_small = predicted_p95_ms(&ts, MigConfig::Small7, 7, 1.0);
        let p_full = predicted_p95_ms(&ts, MigConfig::Full1, 1, 1.0);
        // Full GPU's Time_knee deadline (no /n division) dominates.
        assert!(p_full > p_small, "full={p_full} small={p_small}");
    }

    #[test]
    fn overload_scores_infeasible() {
        let ts = swin(50.0);
        let cap = 7.0 * ServiceModel::new(ts.model.spec(), 1).plateau_qps(0.0);
        let p = predicted_p95_ms(&ts, MigConfig::Small7, 7, cap * 1.5);
        assert!(p >= INFEASIBLE_MS, "{p}");
    }

    #[test]
    fn alloc_tracks_demand() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        // A cold, B hot: B should get most of the slices.
        let alloc =
            alloc_for_rates(&tenants, &[0.2 * u, 4.0 * u], MigConfig::Small7, 0.85).unwrap();
        assert_eq!(alloc.iter().sum::<usize>(), 7);
        assert!(alloc[1] >= 5, "{alloc:?}");
        assert!(alloc[0] >= 1);
        // Symmetric demand: near-even split, deterministic tie-break.
        let even =
            alloc_for_rates(&tenants, &[u, u], MigConfig::Small7, 0.85).unwrap();
        assert_eq!(even, vec![4, 3]);
    }

    #[test]
    fn alloc_rejects_too_many_tenants() {
        let tenants: Vec<TenantSpec> = (0..3).map(|_| swin(25.0)).collect();
        assert!(alloc_for_rates(&tenants, &[1.0, 1.0, 1.0], MigConfig::Full1, 0.85).is_none());
    }

    #[test]
    fn plan_prefers_capacity_under_load() {
        // At rates beyond the full GPU's capacity, only the fine partition
        // is feasible (paper Fig 5: 1g.5gb(7x) aggregate > 7g.40gb(1x)).
        let tenants = vec![swin(25.0)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        let (plan, _) = plan_for_rates(&tenants, &[6.0 * u], 0.85);
        assert_eq!(plan.mig, MigConfig::Small7);
    }

    #[test]
    fn controller_stays_put_on_constant_load() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        let rate = 2.0 * u; // per tenant, comfortably served by [4,3]
        let mut ctrl = ReconfigController::new(
            tenants,
            Plan { mig: MigConfig::Small7, alloc: vec![4, 3] },
            ReconfigPolicy::default(),
        );
        let window = ctrl.window();
        let mut now = 0;
        for _ in 0..40 {
            now += window;
            let per_window = (rate * to_secs(window)) as usize;
            for _ in 0..per_window {
                ctrl.observe_arrival(0);
                ctrl.observe_arrival(1);
            }
            assert!(ctrl.tick(now).is_none(), "thrashes at t={now}");
        }
        assert!(ctrl.events().is_empty());
    }

    #[test]
    fn controller_reallocates_on_skew_and_respects_cooldown() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        let mut ctrl = ReconfigController::new(
            tenants,
            Plan { mig: MigConfig::Small7, alloc: vec![4, 3] },
            ReconfigPolicy::default(),
        );
        let window = ctrl.window();
        let mut now = 0;
        let mut reconfigs = Vec::new();
        // Tenant B runs far past its 3-slice capacity; A idles.
        for _ in 0..20 {
            now += window;
            let a = (0.3 * u * to_secs(window)) as usize;
            let b = (3.8 * u * to_secs(window)) as usize;
            for _ in 0..a {
                ctrl.observe_arrival(0);
            }
            for _ in 0..b {
                ctrl.observe_arrival(1);
            }
            if let Some(plan) = ctrl.tick(now) {
                assert!(plan.alloc[1] > 3, "should shift slices to B: {plan}");
                reconfigs.push(now);
            }
        }
        assert!(!reconfigs.is_empty(), "controller never reacted");
        let cooldown = millis(ctrl.policy().cooldown_s * 1e3);
        for pair in reconfigs.windows(2) {
            assert!(pair[1] - pair[0] >= cooldown, "reconfigs thrash: {reconfigs:?}");
        }
    }

    #[test]
    fn plan_display_is_compact() {
        let p = Plan { mig: MigConfig::Small7, alloc: vec![4, 3] };
        assert_eq!(p.to_string(), "1g.5gb(7x)[4/3]");
        assert_eq!(p.slices(), 7);
    }

    #[test]
    fn cluster_planner_prefers_in_place_reassignment() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        // A wants ~5 slices, B is nearly idle; both serve from GPU0, so
        // every move must be an in-place reassignment there.
        let alloc = vec![vec![3, 4]];
        let moves = plan_cluster_moves(
            &tenants,
            &slices,
            &[4.0 * u, 0.1 * u],
            &alloc,
            &ReconfigPolicy::default(),
        );
        assert!(!moves.is_empty());
        assert!(moves.iter().all(|m| !m.migration), "{moves:?}");
        assert!(moves.iter().all(|m| m.gpu == 0 && m.from == 1 && m.to == 0), "{moves:?}");
    }

    #[test]
    fn cluster_planner_migrates_only_when_the_bar_clears() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        // A owns GPU0 and is deeply overloaded; B idles on GPU1. Relief
        // can only cross GPUs: the first move is a migration (new
        // residency), follow-ups on that GPU are in-place.
        let alloc = vec![vec![7, 0], vec![0, 7]];
        let rates = [9.0 * u, 0.2 * u];
        let mut policy = ReconfigPolicy { migration_s: 0.2, ..Default::default() };
        let moves = plan_cluster_moves(&tenants, &slices, &rates, &alloc, &policy);
        assert!(!moves.is_empty());
        assert!(moves[0].migration && moves[0].gpu == 1 && moves[0].to == 0, "{moves:?}");
        assert!(
            moves.iter().skip(1).all(|m| !m.migration),
            "one residency, then in-place: {moves:?}"
        );
        assert!(moves.len() >= 2, "{moves:?}");

        // An astronomically expensive migration never clears the bar, and
        // no in-place option exists — the planner must emit nothing.
        policy.migration_s = 1e6;
        let gated = plan_cluster_moves(&tenants, &slices, &rates, &alloc, &policy);
        assert!(gated.is_empty(), "{gated:?}");
    }

    #[test]
    fn fleet_planner_never_overflows_a_small_class() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let slices = vec![Slice::new(4, 20), Slice::new(1, 5)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 4).plateau_qps(0.0);
        // Tenant 0 (4g profile) is overloaded on its A100; the only donor
        // slices are tenant 1's 4×1g on a full A30. Freeing one 1g leaves
        // 1 GPC — a 4g can never fit there, so the planner must emit no
        // move that overflows the A30's class capacity (here: none).
        let fleet = vec![GpuClass::A100, GpuClass::A30];
        let alloc = vec![vec![1, 0], vec![0, 4]];
        let rates = [5.0 * u, 0.01];
        let policy = ReconfigPolicy { migration_s: 0.05, ..Default::default() };
        let moves =
            plan_cluster_moves_fleet(&tenants, &slices, &rates, &alloc, &fleet, &policy);
        // Replay: per-GPU class capacity must hold after every move.
        let mut state = alloc.clone();
        for m in &moves {
            state[m.gpu][m.from] -= 1;
            state[m.gpu][m.to] += 1;
            let gpcs: usize = (0..2).map(|i| state[m.gpu][i] * slices[i].gpcs).sum();
            assert!(gpcs <= fleet[m.gpu].gpcs, "class capacity violated by {m:?}");
        }
        // In particular tenant 0's 4g never landed on the A30.
        assert_eq!(state[1][0], 0, "{moves:?}");
    }

    #[test]
    fn try_admit_places_only_into_freed_class_capacity() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let slices = vec![Slice::new(1, 5), Slice::new(4, 20)];
        let fleet = vec![GpuClass::A30];
        // The A30 starts full with 4×1g of tenant 0: nothing to admit.
        let mut ctrl = ClusterReconfigController::with_fleet(
            tenants,
            slices,
            fleet,
            vec![vec![4, 0]],
            ReconfigPolicy::default(),
        );
        assert_eq!(ctrl.try_admit(1), None, "admitted into a full GPU");
        // Drain tenant 0 down to nothing (as rebalances would): now the
        // 4g pending ask fits the A30's 4 free GPCs.
        ctrl.alloc[0][0] = 0;
        assert_eq!(ctrl.try_admit(1), Some(0));
        assert_eq!(ctrl.alloc()[0], vec![0, 1]);
        // And a second replica no longer fits.
        assert_eq!(ctrl.try_admit(1), None);
    }

    #[test]
    fn cluster_controller_applies_hysteresis_and_tracks_alloc() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        let mut ctrl = ClusterReconfigController::new(
            tenants,
            slices,
            vec![vec![4, 3]],
            ReconfigPolicy::default(),
        );
        let window = ctrl.window();
        let mut now = 0;
        // Balanced comfortable load: no rebalancing.
        for _ in 0..10 {
            now += window;
            let per_window = (2.0 * u * to_secs(window)) as usize;
            for _ in 0..per_window {
                ctrl.observe_arrival(0);
                ctrl.observe_arrival(1);
            }
            assert!(ctrl.tick(now).is_none(), "thrashes at t={now}");
        }
        // Skew: B runs far past its share, A idles.
        let mut committed = None;
        for _ in 0..10 {
            now += window;
            let b = (5.5 * u * to_secs(window)) as usize;
            for _ in 0..b {
                ctrl.observe_arrival(1);
            }
            if let Some(moves) = ctrl.tick(now) {
                committed = Some(moves);
                break;
            }
        }
        let moves = committed.expect("controller never reacted to skew");
        assert!(moves.iter().all(|m| m.from == 0 && m.to == 1));
        let total: usize = ctrl.alloc()[0].iter().sum();
        assert_eq!(total, 7, "slices conserved: {:?}", ctrl.alloc());
        assert!(ctrl.alloc()[0][1] > 3);
        assert_eq!(ctrl.events().len(), 1);
        assert_eq!(ctrl.migrations(), 0);
    }

    /// Feed `per_window` arrivals per tenant, close the window, and run
    /// both controller passes (the DES's ReconfigCheck sequence).
    fn drive_window(
        ctrl: &mut ClusterReconfigController,
        now: &mut Nanos,
        per_window: &[usize],
    ) -> Option<ConsolidationAction> {
        *now += ctrl.window();
        for (i, &n) in per_window.iter().enumerate() {
            for _ in 0..n {
                ctrl.observe_arrival(i);
            }
        }
        let _ = ctrl.tick(*now);
        ctrl.tick_consolidation(*now)
    }

    fn consolidating_policy() -> ReconfigPolicy {
        ReconfigPolicy {
            consolidate: true,
            consolidate_util: 0.5,
            consolidate_windows: 3,
            ..Default::default()
        }
    }

    #[test]
    fn consolidation_disabled_by_default_and_noop_before_tick() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
        let mut ctrl = ClusterReconfigController::new(
            tenants,
            slices,
            vec![vec![5, 2], vec![0, 3]],
            ReconfigPolicy::default(),
        );
        // Disabled policy: never consolidates, whatever the load.
        let mut now = 0;
        for _ in 0..10 {
            assert!(drive_window(&mut ctrl, &mut now, &[1, 1]).is_none());
        }
        assert!(ctrl.powered_down().iter().all(|&p| !p));
        // Enabled but tick never called: no rates, no decision.
        let mut cold = ClusterReconfigController::new(
            vec![swin(25.0)],
            vec![Slice::new(1, 5)],
            vec![vec![2]],
            consolidating_policy(),
        );
        assert!(cold.tick_consolidation(secs(1.0)).is_none());
    }

    #[test]
    fn sustained_low_load_powers_down_the_lightest_gpu() {
        let tenants = vec![swin(50.0), swin(50.0)];
        let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        // GPU0: A×5 + B×2; GPU1: B×3 — GPU1 is the lighter victim.
        let mut ctrl = ClusterReconfigController::new(
            tenants,
            slices,
            vec![vec![5, 2], vec![0, 3]],
            consolidating_policy(),
        );
        let window = ctrl.window();
        let per = (0.8 * u * to_secs(window)) as usize; // ~0.8 slices' demand each
        let mut now = 0;
        let mut action = None;
        for w in 0..10 {
            if let Some(a) = drive_window(&mut ctrl, &mut now, &[per, per]) {
                // Hysteresis: never before `consolidate_windows` windows.
                assert!(w + 1 >= ctrl.policy().consolidate_windows, "window {w}");
                action = Some(a);
                break;
            }
        }
        let (gpu, retire, relocate) = match action.expect("low load never consolidated") {
            ConsolidationAction::PowerDown { gpu, retire, relocate } => (gpu, retire, relocate),
            other => panic!("expected a power-down, got {other:?}"),
        };
        assert_eq!(gpu, 1, "victim must be the lighter GPU");
        assert!(ctrl.powered_down()[1] && !ctrl.powered_down()[0]);
        assert_eq!(ctrl.consolidations(), 1);
        assert!(!retire.is_empty(), "surplus replicas should retire");
        // The victim's row is empty and every mover landed on GPU0.
        assert_eq!(ctrl.alloc()[1], vec![0, 0], "{:?}", ctrl.alloc());
        assert!(relocate.iter().all(|r| r.from_gpu == 1 && r.to_gpu == 0), "{relocate:?}");
        // Every tenant keeps at least one instance and enough headroom
        // for the rate that justified the power-down.
        for i in 0..2 {
            let have: usize = ctrl.alloc().iter().map(|g| g[i]).sum();
            assert!(have >= 1, "tenant {i} lost its foothold");
            let need = slices_for_rate(&swin(50.0), Slice::new(1, 5), 0.8 * u, 0.85);
            assert!(have >= need, "tenant {i}: {have} < need {need}");
        }
    }

    #[test]
    fn deficit_wakes_a_parked_gpu_and_cooldown_separates_decisions() {
        let tenants = vec![swin(50.0), swin(50.0)];
        let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        let mut ctrl = ClusterReconfigController::new(
            tenants,
            slices,
            vec![vec![5, 2], vec![0, 3]],
            consolidating_policy(),
        );
        let window = ctrl.window();
        let low = (0.8 * u * to_secs(window)) as usize;
        let mut now = 0;
        let mut down_at = None;
        for _ in 0..10 {
            if let Some(ConsolidationAction::PowerDown { .. }) =
                drive_window(&mut ctrl, &mut now, &[low, low])
            {
                down_at = Some(now);
                break;
            }
        }
        let down_at = down_at.expect("never powered down");
        // Demand outgrows the shrunken provision: the parked GPU wakes
        // (never inside the cooldown the power-down started).
        let high = (6.0 * u * to_secs(window)) as usize;
        let mut woke = None;
        for _ in 0..10 {
            if let Some(a) = drive_window(&mut ctrl, &mut now, &[high, high]) {
                match a {
                    ConsolidationAction::PowerUp { gpu, grants } => {
                        assert_eq!(gpu, 1);
                        assert!(!grants.is_empty());
                    }
                    other => panic!("expected a wake, got {other:?}"),
                }
                woke = Some(now);
                break;
            }
        }
        let woke = woke.expect("deficit never woke the parked GPU");
        assert!(!ctrl.powered_down()[1]);
        assert!(
            woke - down_at >= millis(ctrl.policy().cooldown_s * 1e3),
            "wake inside the power-down cooldown"
        );
        // The woken capacity is real: tenants' holdings grew.
        let total: usize = ctrl.alloc().iter().flatten().sum();
        assert!(total > 0);
        assert!(ctrl.alloc()[1].iter().sum::<usize>() > 0, "{:?}", ctrl.alloc());
    }

    #[test]
    fn consolidation_never_fires_in_the_planners_window() {
        // A window whose tick commits moves starts the shared cooldown,
        // so tick_consolidation must decline the same window.
        let tenants = vec![swin(25.0), swin(25.0)];
        let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        let mut ctrl = ClusterReconfigController::new(
            tenants,
            slices,
            vec![vec![4, 3]],
            ReconfigPolicy { consolidate: true, ..Default::default() },
        );
        let window = ctrl.window();
        let mut now = 0;
        for _ in 0..10 {
            now += window;
            let b = (5.5 * u * to_secs(window)) as usize;
            for _ in 0..b {
                ctrl.observe_arrival(1);
            }
            let moved = ctrl.tick(now).is_some();
            let consolidated = ctrl.tick_consolidation(now).is_some();
            assert!(!(moved && consolidated), "both passes acted in one window");
        }
    }

    #[test]
    fn failed_gpu_displaces_holdings_and_blocks_admission_until_restore() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
        let mut ctrl = ClusterReconfigController::new(
            tenants,
            slices,
            vec![vec![3, 2], vec![0, 0]],
            ReconfigPolicy::default(),
        );
        let displaced = ctrl.fail_gpu(0);
        assert_eq!(displaced, vec![(0, 3), (1, 2)]);
        assert_eq!(ctrl.alloc()[0], vec![0, 0], "failed row must zero");
        assert!(ctrl.gpu_failed()[0]);
        // Admission skips the dead GPU: asks land on GPU1, and once it
        // fills the rest must wait.
        for _ in 0..7 {
            assert_eq!(ctrl.try_admit(0), Some(1));
        }
        assert_eq!(ctrl.try_admit(0), None, "fleet is one GPU short");
        // Repair: the GPU rejoins empty and takes the waiting ask.
        ctrl.restore_gpu(0);
        assert_eq!(ctrl.try_admit(0), Some(0));
    }

    #[test]
    fn planner_never_targets_a_failed_gpu() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        // Three GPUs; GPU2 crashed. A is overloaded on GPU0, so relief
        // wants a migration — it must pick GPU1, never the dead GPU2.
        let mut ctrl = ClusterReconfigController::new(
            tenants,
            slices,
            vec![vec![7, 0], vec![0, 2], vec![0, 0]],
            ReconfigPolicy { migration_s: 0.05, ..Default::default() },
        );
        ctrl.fail_gpu(2);
        let window = ctrl.window();
        let mut now = 0;
        let mut planned = None;
        for _ in 0..10 {
            now += window;
            let a = (9.0 * u * to_secs(window)) as usize;
            for _ in 0..a {
                ctrl.observe_arrival(0);
            }
            if let Some(moves) = ctrl.tick(now) {
                planned = Some(moves);
                break;
            }
        }
        let moves = planned.expect("overload never triggered a rebalance");
        assert!(moves.iter().all(|m| m.gpu != 2), "move onto a dead GPU: {moves:?}");
        assert_eq!(ctrl.alloc()[2], vec![0, 0]);
    }

    #[test]
    fn abort_last_reverts_the_mirror_and_pops_the_event() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        let mut ctrl = ClusterReconfigController::new(
            tenants,
            slices,
            vec![vec![4, 3]],
            ReconfigPolicy::default(),
        );
        let before = ctrl.alloc().to_vec();
        let window = ctrl.window();
        let mut now = 0;
        let mut committed = false;
        for _ in 0..10 {
            now += window;
            let b = (5.5 * u * to_secs(window)) as usize;
            for _ in 0..b {
                ctrl.observe_arrival(1);
            }
            if ctrl.tick(now).is_some() {
                committed = true;
                break;
            }
        }
        assert!(committed, "skew never committed a rebalance");
        assert_ne!(ctrl.alloc(), &before[..]);
        let ev = ctrl.abort_last().expect("an event was committed");
        assert!(!ev.moves.is_empty());
        assert_eq!(ctrl.alloc(), &before[..], "abort must restore the mirror");
        assert!(ctrl.events().is_empty(), "aborted rebalances don't count");
        // Cooldown still stands: an immediate re-tick with the same skew
        // cannot commit inside the window the abort burned.
        for _ in 0..((5.5 * u * to_secs(window)) as usize) {
            ctrl.observe_arrival(1);
        }
        assert!(ctrl.tick(now + 1).is_none(), "abort must not bypass cooldown");
        assert!(ctrl.abort_last().is_none(), "nothing left to abort");
    }

    #[test]
    fn slice_loss_notes_keep_the_mirror_truthful() {
        let tenants = vec![swin(25.0)];
        let slices = vec![Slice::new(1, 5)];
        let mut ctrl = ClusterReconfigController::new(
            tenants,
            slices,
            vec![vec![2]],
            ReconfigPolicy::default(),
        );
        ctrl.note_slice_lost(0, 0);
        assert_eq!(ctrl.alloc()[0], vec![1]);
        ctrl.note_slice_lost(0, 0);
        ctrl.note_slice_lost(0, 0); // saturates at zero
        assert_eq!(ctrl.alloc()[0], vec![0]);
        ctrl.note_slice_restored(0, 0);
        assert_eq!(ctrl.alloc()[0], vec![1]);
    }
}
