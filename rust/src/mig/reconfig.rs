//! Online MIG reconfiguration: windowed rate telemetry, a hysteresis
//! controller with an amortized reconfig-cost model, and a rate-aware
//! partition/allocation planner.
//!
//! PREBA's characterization says the right slicing is workload-dependent;
//! the offline `mig::planner` freezes one answer. Real traffic is diurnal
//! and bursty (`workload::trace`), so the partition — both the slice
//! *geometry* (`MigConfig`) and, under multi-tenancy, the *assignment* of
//! slices to tenants — should track the observed arrival rate. This is the
//! "reconfigurable machine scheduling" problem (Tan et al.,
//! arXiv:2109.11067): repartitioning has a real cost (MIG instances must
//! drain before they can be destroyed/re-created), so the controller only
//! moves when the predicted gain amortizes that cost, and never twice
//! within a cooldown window.
//!
//! Three layers, usable independently:
//! * [`RateWatcher`] — windowed arrival-rate estimation with EWMA
//!   smoothing (the `workload::trace::windowed_rates` telemetry, online).
//! * [`plan_for_rates`] — for observed per-tenant rates, the best
//!   (geometry, slice allocation) under the same analytic latency model
//!   the DES implements (Time_knee/n batching wait + service + an M/D/c
//!   utilization inflation).
//! * [`ReconfigController`] — the decision gate: EWMA telemetry in,
//!   `Option<Plan>` out, with hysteresis deadband, cooldown, and the
//!   amortized cost-benefit check.
//!
//! The DES drivers (`server::sim_driver` single-tenant geometry,
//! `server::multi` multi-tenant slice reallocation) turn an emitted plan
//! into first-class drain/restart events.

use crate::clock::{secs, to_secs, Nanos};
use crate::mig::{MigConfig, ServiceModel};
use crate::models::ModelId;

/// Predicted-latency cap for infeasible (rate >= capacity) operating
/// points, ms. Kept finite so ordering between two overloaded plans still
/// works (more overloaded scores worse).
const INFEASIBLE_MS: f64 = 60_000.0;

/// Controller knobs. Defaults suit the experiment scenarios (periods of
/// seconds); production deployments would scale window/cooldown up with
/// their traffic periods.
#[derive(Debug, Clone)]
pub struct ReconfigPolicy {
    /// Arrival-rate estimation window, seconds (also the decision cadence).
    pub window_s: f64,
    /// EWMA weight of the newest window (1.0 = no smoothing).
    pub ewma_alpha: f64,
    /// Minimum time between two reconfigurations, seconds. Also the
    /// commitment horizon the cost model amortizes over.
    pub cooldown_s: f64,
    /// Hysteresis deadband: a candidate plan must beat the current plan's
    /// predicted worst SLA ratio by at least this relative margin.
    pub min_gain: f64,
    /// Fixed repartition outage per move (instance destroy + create +
    /// server restart), seconds, charged after the affected slices drain.
    pub repartition_s: f64,
    /// Utilization target the allocator sizes slice counts for.
    pub target_util: f64,
}

impl Default for ReconfigPolicy {
    fn default() -> Self {
        ReconfigPolicy {
            window_s: 0.75,
            ewma_alpha: 0.5,
            cooldown_s: 1.5,
            min_gain: 0.15,
            repartition_s: 0.15,
            target_util: 0.85,
        }
    }
}

/// One tenant the controller plans for.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub model: ModelId,
    /// End-to-end p95 SLA, ms.
    pub sla_ms: f64,
    /// Representative input length, seconds (0 for vision).
    pub len_s: f64,
}

impl TenantSpec {
    pub fn new(model: ModelId, sla_ms: f64) -> TenantSpec {
        TenantSpec { model, sla_ms, len_s: crate::mig::planner::default_len(model) }
    }
}

/// A concrete partition decision: slice geometry + per-tenant slice counts
/// (`alloc[i]` vGPUs for tenant `i`; the counts need not exhaust the
/// partition, but the planner always hands out every slice).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Plan {
    pub mig: MigConfig,
    pub alloc: Vec<usize>,
}

impl Plan {
    /// Single-tenant plan owning the whole partition.
    pub fn single(mig: MigConfig) -> Plan {
        Plan { mig, alloc: vec![mig.vgpus()] }
    }

    pub fn slices(&self) -> usize {
        self.alloc.iter().sum()
    }
}

impl std::fmt::Display for Plan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}[", self.mig.name())?;
        for (i, a) in self.alloc.iter().enumerate() {
            if i > 0 {
                f.write_str("/")?;
            }
            write!(f, "{a}")?;
        }
        f.write_str("]")
    }
}

/// One committed reconfiguration (timeline entry).
#[derive(Debug, Clone)]
pub struct ReconfigEvent {
    pub at: Nanos,
    pub plan: Plan,
    /// Smoothed per-tenant rates that justified the move, queries/s.
    pub rates: Vec<f64>,
    /// Predicted worst-tenant p95 improvement, ms.
    pub predicted_gain_ms: f64,
}

/// Windowed arrival-rate estimator with EWMA smoothing.
#[derive(Debug, Clone)]
pub struct RateWatcher {
    window_start: Nanos,
    count: u64,
    alpha: f64,
    ewma: f64,
    primed: bool,
}

impl RateWatcher {
    pub fn new(alpha: f64) -> RateWatcher {
        RateWatcher { window_start: 0, count: 0, alpha, ewma: 0.0, primed: false }
    }

    /// Count one arrival in the current window.
    pub fn observe(&mut self) {
        self.count += 1;
    }

    /// Close the window ending at `now`; returns the smoothed estimate.
    pub fn roll(&mut self, now: Nanos) -> f64 {
        let span_s = to_secs(now.saturating_sub(self.window_start)).max(1e-9);
        let inst = self.count as f64 / span_s;
        if self.primed {
            self.ewma = self.alpha * inst + (1.0 - self.alpha) * self.ewma;
        } else {
            self.ewma = inst;
            self.primed = true;
        }
        self.window_start = now;
        self.count = 0;
        self.ewma
    }

    /// Current smoothed rate, queries/s.
    pub fn rate(&self) -> f64 {
        self.ewma
    }
}

/// Analytic p95 prediction for `rate_qps` offered to `n_vgpus` slices of
/// `mig`'s geometry — the same latency structure the DES produces: a
/// request waits for its batch (up to the Time_knee/n deadline the
/// batching policy uses), executes, and sees M/D/c-style queueing
/// inflation as utilization rises. Deliberately mirrors the simulator so
/// the controller's ranking matches simulated outcomes.
pub fn predicted_p95_ms(spec: &TenantSpec, mig: MigConfig, n_vgpus: usize, rate_qps: f64) -> f64 {
    if n_vgpus == 0 {
        return 2.0 * INFEASIBLE_MS;
    }
    let sm = ServiceModel::new(spec.model.spec(), mig.gpcs_per_vgpu());
    let len = spec.len_s;
    let per_vgpu = rate_qps / n_vgpus as f64;
    let rho = per_vgpu / sm.plateau_qps(len);
    if rho >= 0.999 {
        return INFEASIBLE_MS * rho.min(10.0);
    }
    let knee = sm.knee(len);
    // The drivers' dynamic policy: Batch_max = knee, Time_queue = T(knee)/n.
    let tq_s = sm.exec_secs(knee, len) / n_vgpus as f64;
    // Batch the offered rate fills before the deadline fires.
    let fill = (per_vgpu * tq_s).floor() as usize;
    let b = (fill + 1).clamp(1, knee);
    // Head-of-line wait: the deadline when the queue can't fill the knee
    // in time, else the knee fill time.
    let wait_s = if b >= knee { (knee as f64 / per_vgpu.max(1e-9)).min(tq_s) } else { tq_s };
    let exec_s = sm.exec_secs(b, len);
    let inflation = 1.0 + rho * rho / (2.0 * (1.0 - rho));
    (wait_s + exec_s * inflation) * 1e3 * 1.10
}

/// Allocate `mig`'s slices across tenants for the observed rates: everyone
/// gets at least one slice, then each remaining slice goes to the tenant
/// with the largest unmet demand (in slices, sized at `target_util`).
/// Deterministic: ties break toward the lowest tenant index. `None` when
/// the partition has fewer slices than tenants.
pub fn alloc_for_rates(
    tenants: &[TenantSpec],
    rates: &[f64],
    mig: MigConfig,
    target_util: f64,
) -> Option<Vec<usize>> {
    let n = mig.vgpus();
    let t = tenants.len();
    if t == 0 || t > n {
        return None;
    }
    let need: Vec<f64> = tenants
        .iter()
        .zip(rates.iter())
        .map(|(ts, &r)| {
            let per_slice = ServiceModel::new(ts.model.spec(), mig.gpcs_per_vgpu())
                .plateau_qps(ts.len_s);
            r / (per_slice * target_util).max(1e-9)
        })
        .collect();
    let mut alloc = vec![1usize; t];
    for _ in t..n {
        let mut best = 0usize;
        let mut best_deficit = f64::NEG_INFINITY;
        for (i, (&n_i, &a)) in need.iter().zip(alloc.iter()).enumerate() {
            let deficit = n_i - a as f64;
            if deficit > best_deficit {
                best_deficit = deficit;
                best = i;
            }
        }
        alloc[best] += 1;
    }
    Some(alloc)
}

/// Worst tenant's (predicted p95 / SLA) under `plan`, plus that p95 and
/// the tenant index.
pub fn worst_ratio(tenants: &[TenantSpec], rates: &[f64], plan: &Plan) -> (f64, f64, usize) {
    let mut ratio = 0.0;
    let mut p95 = 0.0;
    let mut idx = 0;
    for (i, (ts, (&r, &a))) in
        tenants.iter().zip(rates.iter().zip(plan.alloc.iter())).enumerate()
    {
        let p = predicted_p95_ms(ts, plan.mig, a, r);
        let q = p / ts.sla_ms.max(1e-9);
        if q > ratio {
            ratio = q;
            p95 = p;
            idx = i;
        }
    }
    (ratio, p95, idx)
}

/// Best (geometry, allocation) for the observed rates: evaluates every
/// MIG configuration with at least one slice per tenant and returns the
/// plan minimizing the worst tenant's predicted-p95/SLA ratio, plus that
/// ratio. Deterministic (fixed search order, strict improvement).
pub fn plan_for_rates(tenants: &[TenantSpec], rates: &[f64], target_util: f64) -> (Plan, f64) {
    assert!(!tenants.is_empty() && tenants.len() <= 7, "1..=7 tenants supported");
    let mut best: Option<(Plan, f64)> = None;
    for mig in MigConfig::ALL {
        let Some(alloc) = alloc_for_rates(tenants, rates, mig, target_util) else {
            continue;
        };
        let plan = Plan { mig, alloc };
        let (ratio, _, _) = worst_ratio(tenants, rates, &plan);
        let better = match &best {
            None => true,
            Some((_, b)) => ratio < *b,
        };
        if better {
            best = Some((plan, ratio));
        }
    }
    best.expect("Small7 admits up to 7 tenants")
}

/// The online decision gate. Feed it arrivals (`observe_arrival`) and call
/// [`ReconfigController::tick`] once per window; it returns `Some(plan)`
/// only when a repartition clears hysteresis, cooldown, and the amortized
/// cost-benefit check.
#[derive(Debug)]
pub struct ReconfigController {
    policy: ReconfigPolicy,
    tenants: Vec<TenantSpec>,
    watchers: Vec<RateWatcher>,
    plan: Plan,
    last_reconfig: Option<Nanos>,
    events: Vec<ReconfigEvent>,
}

impl ReconfigController {
    pub fn new(tenants: Vec<TenantSpec>, initial: Plan, policy: ReconfigPolicy) -> Self {
        assert_eq!(tenants.len(), initial.alloc.len(), "plan/tenant arity mismatch");
        assert!(!tenants.is_empty() && tenants.len() <= 7, "1..=7 tenants supported");
        let watchers = tenants.iter().map(|_| RateWatcher::new(policy.ewma_alpha)).collect();
        ReconfigController {
            policy,
            tenants,
            watchers,
            plan: initial,
            last_reconfig: None,
            events: Vec::new(),
        }
    }

    /// Decision cadence as virtual nanoseconds.
    pub fn window(&self) -> Nanos {
        secs(self.policy.window_s)
    }

    pub fn policy(&self) -> &ReconfigPolicy {
        &self.policy
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    pub fn events(&self) -> &[ReconfigEvent] {
        &self.events
    }

    /// Smoothed per-tenant rate estimates, queries/s.
    pub fn rates(&self) -> Vec<f64> {
        self.watchers.iter().map(RateWatcher::rate).collect()
    }

    /// Count one arrival for tenant `i` in the current window.
    pub fn observe_arrival(&mut self, i: usize) {
        self.watchers[i].observe();
    }

    /// Close the telemetry window without making a decision (used while a
    /// previous reconfiguration is still draining, or after the workload's
    /// final arrival).
    pub fn roll_only(&mut self, now: Nanos) {
        for w in &mut self.watchers {
            w.roll(now);
        }
    }

    /// Close the window at `now` and decide. `Some(plan)` commits the
    /// reconfiguration (the caller must then drain + apply it).
    pub fn tick(&mut self, now: Nanos) -> Option<Plan> {
        let rates: Vec<f64> = self.watchers.iter_mut().map(|w| w.roll(now)).collect();
        if let Some(t) = self.last_reconfig {
            if now < t.saturating_add(secs(self.policy.cooldown_s)) {
                return None;
            }
        }
        let (cur_ratio, cur_p95, worst_idx) = worst_ratio(&self.tenants, &rates, &self.plan);
        let (cand, cand_ratio) = plan_for_rates(&self.tenants, &rates, self.policy.target_util);
        if cand == self.plan {
            return None;
        }
        // Hysteresis deadband: ignore marginal improvements.
        if cand_ratio >= cur_ratio * (1.0 - self.policy.min_gain) {
            return None;
        }
        // Amortized reconfig-cost model: moving `moved` slices takes them
        // offline for ~repartition_s, displacing their share of the load
        // by ~repartition_s each (latency mass in query-seconds). The
        // switch must win that back, at the worst tenant's rate, within
        // one cooldown (the minimum commitment horizon).
        let (_, cand_p95, _) = worst_ratio(&self.tenants, &rates, &cand);
        let total_rate: f64 = rates.iter().sum();
        let moved = if cand.mig == self.plan.mig {
            let diff: usize = cand
                .alloc
                .iter()
                .zip(self.plan.alloc.iter())
                .map(|(&a, &b)| a.abs_diff(b))
                .sum();
            (diff / 2).max(1) as f64
        } else {
            self.plan.slices() as f64
        };
        let displaced_qps = total_rate * moved / self.plan.slices().max(1) as f64;
        let cost_qs = displaced_qps * self.policy.repartition_s * self.policy.repartition_s;
        let saved_qs =
            (cur_p95 - cand_p95) * 1e-3 * rates[worst_idx] * self.policy.cooldown_s;
        if saved_qs <= cost_qs {
            return None;
        }
        self.last_reconfig = Some(now);
        self.plan = cand.clone();
        self.events.push(ReconfigEvent {
            at: now,
            plan: cand.clone(),
            rates,
            predicted_gain_ms: cur_p95 - cand_p95,
        });
        Some(cand)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::millis;

    fn swin(sla_ms: f64) -> TenantSpec {
        TenantSpec { model: ModelId::SwinTransformer, sla_ms, len_s: 0.0 }
    }

    #[test]
    fn watcher_estimates_rate_and_smooths() {
        let mut w = RateWatcher::new(0.5);
        for _ in 0..100 {
            w.observe();
        }
        let r1 = w.roll(secs(1.0));
        assert!((r1 - 100.0).abs() < 1e-9, "{r1}");
        // Next window empty: EWMA halves rather than dropping to zero.
        let r2 = w.roll(secs(2.0));
        assert!((r2 - 50.0).abs() < 1e-9, "{r2}");
    }

    #[test]
    fn low_rate_prediction_includes_batching_deadline() {
        // A lone request waits the full Time_queue before executing.
        let ts = swin(50.0);
        let p_small = predicted_p95_ms(&ts, MigConfig::Small7, 7, 1.0);
        let p_full = predicted_p95_ms(&ts, MigConfig::Full1, 1, 1.0);
        // Full GPU's Time_knee deadline (no /n division) dominates.
        assert!(p_full > p_small, "full={p_full} small={p_small}");
    }

    #[test]
    fn overload_scores_infeasible() {
        let ts = swin(50.0);
        let cap = 7.0 * ServiceModel::new(ts.model.spec(), 1).plateau_qps(0.0);
        let p = predicted_p95_ms(&ts, MigConfig::Small7, 7, cap * 1.5);
        assert!(p >= INFEASIBLE_MS, "{p}");
    }

    #[test]
    fn alloc_tracks_demand() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        // A cold, B hot: B should get most of the slices.
        let alloc =
            alloc_for_rates(&tenants, &[0.2 * u, 4.0 * u], MigConfig::Small7, 0.85).unwrap();
        assert_eq!(alloc.iter().sum::<usize>(), 7);
        assert!(alloc[1] >= 5, "{alloc:?}");
        assert!(alloc[0] >= 1);
        // Symmetric demand: near-even split, deterministic tie-break.
        let even =
            alloc_for_rates(&tenants, &[u, u], MigConfig::Small7, 0.85).unwrap();
        assert_eq!(even, vec![4, 3]);
    }

    #[test]
    fn alloc_rejects_too_many_tenants() {
        let tenants: Vec<TenantSpec> = (0..3).map(|_| swin(25.0)).collect();
        assert!(alloc_for_rates(&tenants, &[1.0, 1.0, 1.0], MigConfig::Full1, 0.85).is_none());
    }

    #[test]
    fn plan_prefers_capacity_under_load() {
        // At rates beyond the full GPU's capacity, only the fine partition
        // is feasible (paper Fig 5: 1g.5gb(7x) aggregate > 7g.40gb(1x)).
        let tenants = vec![swin(25.0)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        let (plan, _) = plan_for_rates(&tenants, &[6.0 * u], 0.85);
        assert_eq!(plan.mig, MigConfig::Small7);
    }

    #[test]
    fn controller_stays_put_on_constant_load() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        let rate = 2.0 * u; // per tenant, comfortably served by [4,3]
        let mut ctrl = ReconfigController::new(
            tenants,
            Plan { mig: MigConfig::Small7, alloc: vec![4, 3] },
            ReconfigPolicy::default(),
        );
        let window = ctrl.window();
        let mut now = 0;
        for _ in 0..40 {
            now += window;
            let per_window = (rate * to_secs(window)) as usize;
            for _ in 0..per_window {
                ctrl.observe_arrival(0);
                ctrl.observe_arrival(1);
            }
            assert!(ctrl.tick(now).is_none(), "thrashes at t={now}");
        }
        assert!(ctrl.events().is_empty());
    }

    #[test]
    fn controller_reallocates_on_skew_and_respects_cooldown() {
        let tenants = vec![swin(25.0), swin(25.0)];
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        let mut ctrl = ReconfigController::new(
            tenants,
            Plan { mig: MigConfig::Small7, alloc: vec![4, 3] },
            ReconfigPolicy::default(),
        );
        let window = ctrl.window();
        let mut now = 0;
        let mut reconfigs = Vec::new();
        // Tenant B runs far past its 3-slice capacity; A idles.
        for _ in 0..20 {
            now += window;
            let a = (0.3 * u * to_secs(window)) as usize;
            let b = (3.8 * u * to_secs(window)) as usize;
            for _ in 0..a {
                ctrl.observe_arrival(0);
            }
            for _ in 0..b {
                ctrl.observe_arrival(1);
            }
            if let Some(plan) = ctrl.tick(now) {
                assert!(plan.alloc[1] > 3, "should shift slices to B: {plan}");
                reconfigs.push(now);
            }
        }
        assert!(!reconfigs.is_empty(), "controller never reacted");
        let cooldown = millis(ctrl.policy().cooldown_s * 1e3);
        for pair in reconfigs.windows(2) {
            assert!(pair[1] - pair[0] >= cooldown, "reconfigs thrash: {reconfigs:?}");
        }
    }

    #[test]
    fn plan_display_is_compact() {
        let p = Plan { mig: MigConfig::Small7, alloc: vec![4, 3] };
        assert_eq!(p.to_string(), "1g.5gb(7x)[4/3]");
        assert_eq!(p.slices(), 7);
    }
}
