//! Fragmentation-aware slice placement over a multi-GPU inventory.
//!
//! Multi-tenant MIG serving packs slice requests (a tenant wants `k`
//! instances of some profile) onto GPUs. Naive first-fit in arrival order
//! strands GPCs behind awkward remainders — the fragmentation problem of
//! GPU-cluster schedulers (Ting et al., arXiv:2512.16099). Best-fit-
//! decreasing places big slices first and each into the tightest GPU that
//! still fits, which keeps contiguous room for large profiles and
//! measurably raises admitted capacity.
//!
//! The inventory may be **heterogeneous** ([`pack_fleet`]): every bin
//! carries its own [`GpuClass`] capacity (A100 7-GPC, A30-style 4-GPC),
//! and an ask that exceeds a class (a `7g.40gb` on an A30) is rejected
//! per-GPU — it simply never fits that bin — not fleet-wide.
//!
//! This module is analytic (no DES): `server::multi` and
//! `server::cluster` consume per-GPU allocations, and the `packing` /
//! `cluster` experiments compare strategies.
//!
//! ```
//! use preba::mig::placement::{pack_fleet, SliceAsk};
//! use preba::mig::{GpuClass, PackStrategy, Slice};
//!
//! // One 7g ask over [A100, A30]: only the A100 can host it.
//! let asks = vec![SliceAsk { tenant: 0, slice: Slice::new(7, 40) }; 2];
//! let p = pack_fleet(&asks, &[GpuClass::A100, GpuClass::A30], PackStrategy::BestFit);
//! assert_eq!(p.placements, vec![(asks[0], 0)]);
//! assert_eq!(p.rejected.len(), 1);
//! ```

use super::partition::{GpuClass, Slice};

/// Packing strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackStrategy {
    /// Arrival order, first GPU with room — the naive baseline.
    FirstFit,
    /// Fragmentation-aware: largest slices first, each into the feasible
    /// GPU with the fewest free GPCs left (best-fit-decreasing).
    BestFit,
    /// Fragmentation-gradient descent (Ting et al., arXiv:2512.16099):
    /// largest slices first, each onto the feasible GPU where placing it
    /// grows the demand-weighted fragment measure ([`GpuBin::frag_gpcs`])
    /// the least. Unlike best-fit it looks at what the *remaining demand
    /// mix* can still use, so it avoids leaving free GPCs that no pending
    /// profile fits.
    FragGradient,
}

impl PackStrategy {
    pub fn label(&self) -> &'static str {
        match self {
            PackStrategy::FirstFit => "first-fit (arrival order)",
            PackStrategy::BestFit => "best-fit decreasing",
            PackStrategy::FragGradient => "frag-gradient descent",
        }
    }
}

/// One requested MIG instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SliceAsk {
    /// Requesting tenant (opaque id, reported back in placements).
    pub tenant: usize,
    pub slice: Slice,
}

/// One GPU's class, remaining capacity, and its placed instances.
#[derive(Debug, Clone)]
pub struct GpuBin {
    /// The GPU class this bin was created from (its capacity ceiling).
    pub class: GpuClass,
    pub gpcs_free: usize,
    pub mem_free_gb: usize,
    pub placed: Vec<SliceAsk>,
}

impl GpuBin {
    fn new(class: GpuClass) -> GpuBin {
        GpuBin { class, gpcs_free: class.gpcs, mem_free_gb: class.mem_gb, placed: Vec::new() }
    }

    /// Can this GPU still host `s`? (Compute and memory budgets; mixed
    /// profiles on one GPU are allowed as long as both budgets hold.)
    pub fn fits(&self, s: &Slice) -> bool {
        s.is_legal() && s.gpcs <= self.gpcs_free && s.mem_gb <= self.mem_free_gb
    }

    fn place(&mut self, ask: SliceAsk) {
        self.gpcs_free -= ask.slice.gpcs;
        self.mem_free_gb -= ask.slice.mem_gb;
        self.placed.push(ask);
    }

    /// Fragment measure of this bin under a demand `mix` of
    /// `(profile, weight)` pairs (Ting et al., arXiv:2512.16099, adapted
    /// to discrete MIG profiles): from each profile's perspective, the
    /// bin's free GPCs are *fragmented* when the bin cannot host even one
    /// more instance of that profile — they exist but serve none of that
    /// demand. The measure is the weight-averaged fragmented free GPCs;
    /// 0 when every profile in the mix still fits (or the mix is empty).
    pub fn frag_gpcs(&self, mix: &[(Slice, f64)]) -> f64 {
        let total: f64 = mix.iter().map(|&(_, w)| w).sum();
        if total <= 0.0 {
            return 0.0;
        }
        let stranded: f64 = mix
            .iter()
            .filter(|(s, _)| !self.fits(s))
            .map(|&(_, w)| w * self.gpcs_free as f64)
            .sum();
        stranded / total
    }

    /// How much the fragment measure grows if `s` is placed here (can be
    /// negative: filling a bin completely removes its free GPCs from
    /// every profile's fragmented view). Callers must check
    /// [`GpuBin::fits`] first.
    pub fn frag_gradient(&self, s: &Slice, mix: &[(Slice, f64)]) -> f64 {
        let after = GpuBin {
            class: self.class,
            gpcs_free: self.gpcs_free - s.gpcs,
            mem_free_gb: self.mem_free_gb - s.mem_gb,
            placed: Vec::new(),
        };
        after.frag_gpcs(mix) - self.frag_gpcs(mix)
    }
}

/// Result of packing an ask list onto `n` GPUs.
#[derive(Debug, Clone)]
pub struct Packing {
    pub bins: Vec<GpuBin>,
    /// (ask, gpu index) in placement order.
    pub placements: Vec<(SliceAsk, usize)>,
    pub rejected: Vec<SliceAsk>,
}

impl Packing {
    /// GPCs of admitted asks (capacity actually serving traffic).
    pub fn admitted_gpcs(&self) -> usize {
        self.placements.iter().map(|(a, _)| a.slice.gpcs).sum()
    }

    /// GPCs requested in total (admitted + rejected).
    pub fn asked_gpcs(&self) -> usize {
        self.admitted_gpcs() + self.rejected.iter().map(|a| a.slice.gpcs).sum::<usize>()
    }

    /// Fraction of requested GPCs admitted.
    pub fn admitted_frac(&self) -> f64 {
        let asked = self.asked_gpcs();
        if asked == 0 {
            1.0
        } else {
            self.admitted_gpcs() as f64 / asked as f64
        }
    }

    /// GPCs left idle while demand was turned away. Zero when everything
    /// was admitted (spare capacity is headroom, not fragmentation).
    pub fn stranded_gpcs(&self) -> usize {
        if self.rejected.is_empty() {
            0
        } else {
            self.bins.iter().map(|b| b.gpcs_free).sum()
        }
    }

    /// Total GPCs the inventory offers (sum of per-bin class capacity —
    /// NOT `7 × bins`, which over-counts a heterogeneous fleet).
    pub fn inventory_gpcs(&self) -> usize {
        self.bins.iter().map(|b| b.class.gpcs).sum()
    }

    /// Stranded fraction of the inventory.
    pub fn fragmentation(&self) -> f64 {
        let inv = self.inventory_gpcs();
        if inv == 0 {
            0.0
        } else {
            self.stranded_gpcs() as f64 / inv as f64
        }
    }
}

/// The worked adversarial example shared by this module's unit tests and
/// the `packing` experiment: small-first arrival order tricks first-fit
/// into stranding a GPC on 2 GPUs (admits 13/17 GPCs), while
/// best-fit-decreasing packs both GPUs perfectly (14/17, 0 stranded).
/// One definition so the experiment report and the pinning test can't
/// drift apart.
pub fn adversarial_demo() -> Vec<SliceAsk> {
    let mk = |tenant, gpcs, mem| SliceAsk { tenant, slice: Slice::new(gpcs, mem) };
    vec![
        mk(0, 1, 5),
        mk(0, 1, 5),
        mk(1, 1, 5),
        mk(1, 3, 20),
        mk(2, 3, 20),
        mk(2, 4, 20),
        mk(3, 4, 20),
    ]
}

/// Pack `asks` onto `n_gpus` A100s ([`pack_fleet`] over a homogeneous
/// [`GpuClass::A100`] inventory).
pub fn pack(asks: &[SliceAsk], n_gpus: usize, strategy: PackStrategy) -> Packing {
    pack_fleet(asks, &vec![GpuClass::A100; n_gpus], strategy)
}

/// Pack `asks` onto a (possibly heterogeneous) `fleet`. Deterministic:
/// stable ordering, ties break toward the lowest GPU index. An ask that
/// exceeds a bin's class capacity simply never fits that bin; it is
/// rejected only when NO bin of the fleet can host it.
pub fn pack_fleet(asks: &[SliceAsk], fleet: &[GpuClass], strategy: PackStrategy) -> Packing {
    let mut bins: Vec<GpuBin> = fleet.iter().map(|&c| GpuBin::new(c)).collect();
    let mut order: Vec<usize> = (0..asks.len()).collect();
    if strategy != PackStrategy::FirstFit {
        // Largest first; stable sort keeps arrival order among equals.
        order.sort_by(|&a, &b| asks[b].slice.gpcs.cmp(&asks[a].slice.gpcs));
    }
    // Demand mix for the frag gradient: every legal profile in the ask
    // list, weighted by the GPCs it asks for in total.
    let mut mix: Vec<(Slice, f64)> = Vec::new();
    if strategy == PackStrategy::FragGradient {
        for a in asks.iter().filter(|a| a.slice.is_legal()) {
            match mix.iter_mut().find(|(s, _)| *s == a.slice) {
                Some((_, w)) => *w += a.slice.gpcs as f64,
                None => mix.push((a.slice, a.slice.gpcs as f64)),
            }
        }
    }
    let mut placements = Vec::new();
    let mut rejected = Vec::new();
    for i in order {
        let ask = asks[i];
        let target = match strategy {
            PackStrategy::FirstFit => bins.iter().position(|b| b.fits(&ask.slice)),
            PackStrategy::BestFit => bins
                .iter()
                .enumerate()
                .filter(|(_, b)| b.fits(&ask.slice))
                .min_by_key(|(j, b)| (b.gpcs_free, *j))
                .map(|(j, _)| j),
            PackStrategy::FragGradient => bins
                .iter()
                .enumerate()
                .filter(|(_, b)| b.fits(&ask.slice))
                .map(|(j, b)| (j, b.frag_gradient(&ask.slice, &mix)))
                .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
                .map(|(j, _)| j),
        };
        match target {
            Some(j) => {
                bins[j].place(ask);
                placements.push((ask, j));
            }
            None => rejected.push(ask),
        }
    }
    Packing { bins, placements, rejected }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ask(tenant: usize, gpcs: usize, mem: usize) -> SliceAsk {
        SliceAsk { tenant, slice: Slice::new(gpcs, mem) }
    }

    #[test]
    fn best_fit_beats_first_fit_on_adversarial_order() {
        let asks = adversarial_demo();
        let ff = pack(&asks, 2, PackStrategy::FirstFit);
        let bf = pack(&asks, 2, PackStrategy::BestFit);
        assert_eq!(ff.admitted_gpcs(), 13, "{ff:?}");
        assert_eq!(ff.stranded_gpcs(), 1);
        assert_eq!(bf.admitted_gpcs(), 14, "{bf:?}");
        assert_eq!(bf.stranded_gpcs(), 0);
        assert!(bf.admitted_frac() > ff.admitted_frac());
    }

    #[test]
    fn memory_budget_blocks_placement() {
        // Two 3g.20gb fit one GPU on GPCs (6 <= 7) and memory (40), but a
        // third 1g.5gb must fail on memory despite a free GPC.
        let asks = vec![ask(0, 3, 20), ask(0, 3, 20), ask(1, 1, 5)];
        let p = pack(&asks, 1, PackStrategy::FirstFit);
        assert_eq!(p.placements.len(), 2);
        assert_eq!(p.rejected.len(), 1);
        assert_eq!(p.bins[0].gpcs_free, 1);
        assert_eq!(p.bins[0].mem_free_gb, 0);
    }

    #[test]
    fn illegal_profiles_rejected() {
        let p = pack(&[ask(0, 5, 20)], 2, PackStrategy::BestFit);
        assert!(p.placements.is_empty());
        assert_eq!(p.rejected.len(), 1);
    }

    #[test]
    fn everything_admitted_means_no_fragmentation() {
        let p = pack(&[ask(0, 7, 40)], 2, PackStrategy::FirstFit);
        assert_eq!(p.rejected.len(), 0);
        assert_eq!(p.stranded_gpcs(), 0);
        assert_eq!(p.fragmentation(), 0.0);
        assert_eq!(p.admitted_frac(), 1.0);
    }

    #[test]
    fn deterministic() {
        let asks = adversarial_demo();
        for strategy in
            [PackStrategy::FirstFit, PackStrategy::BestFit, PackStrategy::FragGradient]
        {
            let a = pack(&asks, 3, strategy);
            let b = pack(&asks, 3, strategy);
            assert_eq!(a.placements, b.placements);
            assert_eq!(a.rejected, b.rejected);
        }
    }

    #[test]
    fn frag_measure_counts_only_unhostable_demand() {
        let bin = GpuBin {
            class: GpuClass::A100,
            gpcs_free: 2,
            mem_free_gb: 10,
            placed: Vec::new(),
        };
        // 3g.20gb can no longer land here, so its share of the mix sees
        // both free GPCs stranded; 1g.5gb still fits and sees none.
        let mix = [(Slice::new(3, 20), 3.0), (Slice::new(1, 5), 1.0)];
        assert!((bin.frag_gpcs(&mix) - (3.0 * 2.0) / 4.0).abs() < 1e-12);
        // An empty (or fully satisfiable) mix has nothing to strand.
        assert_eq!(bin.frag_gpcs(&[]), 0.0);
        assert_eq!(bin.frag_gpcs(&[(Slice::new(1, 5), 1.0)]), 0.0);
    }

    #[test]
    fn frag_gradient_keeps_bins_large_profile_capable() {
        // Best-fit tightest-bin packing piles 3g+2g+1g onto one A100,
        // leaving a 1-GPC stub no profile in the mix can use. The frag
        // gradient sees that stranding coming and spreads the small
        // slices, so BOTH GPUs stay able to host another 3g.20gb.
        let asks = vec![ask(0, 3, 20), ask(1, 2, 10), ask(2, 1, 5)];
        let big = Slice::new(3, 20);
        let bf = pack(&asks, 2, PackStrategy::BestFit);
        let fg = pack(&asks, 2, PackStrategy::FragGradient);
        assert!(bf.rejected.is_empty() && fg.rejected.is_empty());
        assert!(
            bf.bins.iter().any(|b| !b.fits(&big)),
            "best-fit should strand a bin below 3g here: {bf:?}"
        );
        assert!(
            fg.bins.iter().all(|b| b.fits(&big)),
            "frag gradient must keep every bin 3g-capable: {fg:?}"
        );
        assert_eq!(fg.bins[0].gpcs_free, 4);
        assert_eq!(fg.bins[1].gpcs_free, 4);
    }

    #[test]
    fn hetero_bins_cap_at_their_own_class() {
        use crate::mig::GpuClass;
        // 2×4g over [A30, A30]: one per GPU (4 GPCs each), nothing strands.
        let asks = vec![ask(0, 4, 20), ask(0, 4, 20)];
        let p = pack_fleet(&asks, &[GpuClass::A30, GpuClass::A30], PackStrategy::FirstFit);
        assert_eq!(p.placements.len(), 2);
        assert_eq!(p.bins[0].gpcs_free, 0);
        assert_eq!(p.bins[1].gpcs_free, 0);
        assert_eq!(p.inventory_gpcs(), 8);
        // A 7g ask can never land on a 4-GPC class.
        let p = pack_fleet(&[ask(0, 7, 40)], &[GpuClass::A30; 3], PackStrategy::BestFit);
        assert!(p.placements.is_empty());
        assert_eq!(p.rejected.len(), 1);
    }

    #[test]
    fn best_fit_prefers_the_tightest_class() {
        use crate::mig::GpuClass;
        // BFD puts the 4g on the A30 (tightest feasible bin), leaving the
        // A100 whole for the 7g; first-fit burns the A100 on the 4g and
        // must reject the 7g.
        let asks = vec![ask(0, 4, 20), ask(1, 7, 40)];
        let fleet = [GpuClass::A100, GpuClass::A30];
        let bf = pack_fleet(&asks, &fleet, PackStrategy::BestFit);
        assert_eq!(bf.rejected.len(), 0, "{bf:?}");
        assert_eq!(bf.placements, vec![(asks[1], 0), (asks[0], 1)]);
        let ff = pack_fleet(&asks, &fleet, PackStrategy::FirstFit);
        assert_eq!(ff.rejected.len(), 1, "{ff:?}");
        // Stranded metric scores against per-class inventory (11 GPCs).
        assert_eq!(ff.stranded_gpcs(), 7);
        assert!((ff.fragmentation() - 7.0 / 11.0).abs() < 1e-12);
    }
}
