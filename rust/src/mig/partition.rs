//! A100 MIG partition geometry (paper §2.2, Fig 2).
//!
//! A vGPU slice is built from GPCs (compute) and L2/DRAM slices (memory).
//! NVIDIA only allows specific "Mg.Ngb" combinations; this module encodes
//! the A100-40GB instance profiles and the homogeneous partitions the
//! paper evaluates: `1g.5gb(7x)`, `2g.10gb(3x)`, `7g.40gb(1x)`.

/// Compute capacity of one A100: 7 GPCs. Only the [`GpuClass::A100`]
/// preset may read this directly; everything downstream (the inventory
/// packer `placement::GpuBin`, the cross-GPU planner `reconfig`) goes
/// through a [`GpuClass`] so per-GPU capacity models cannot drift apart.
pub const A100_GPCS: usize = 7;

/// Memory capacity of one A100-40GB, GB (8 L2/DRAM slices). Like
/// [`A100_GPCS`], routed through [`GpuClass::A100`].
pub const A100_MEM_GB: usize = 40;

/// One GPU class of a (possibly heterogeneous) fleet: its compute and
/// memory capacity. PREBA's evaluation assumes a homogeneous pool of
/// A100s; real MIG fleets mix GPU classes (ParvaGPU, arXiv:2409.14447),
/// and placement quality hinges on scoring each GPU against its *own*
/// capacity — a `7g.40gb` ask must be rejected per-GPU on a 4-GPC class,
/// not fleet-wide.
///
/// ```
/// use preba::mig::{GpuClass, Slice};
///
/// assert!(GpuClass::A100.supports(&Slice::new(7, 40)));
/// assert!(!GpuClass::A30.supports(&Slice::new(7, 40))); // 7g needs 7 GPCs
/// assert!(GpuClass::A30.supports(&Slice::new(4, 20)));
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GpuClass {
    /// Short label (`a100`, `a30`) used by fleet specs and reports.
    pub name: &'static str,
    /// GPCs this class exposes to MIG instances.
    pub gpcs: usize,
    /// DRAM this class exposes, GB.
    pub mem_gb: usize,
}

impl GpuClass {
    /// The paper's testbed GPU: A100-40GB, 7 GPCs.
    pub const A100: GpuClass = GpuClass { name: "a100", gpcs: A100_GPCS, mem_gb: A100_MEM_GB };

    /// An A30-style 4-GPC / 24 GB inventory class: the half-height MIG
    /// part real fleets mix with A100s. `7g.40gb` (and any profile above
    /// 4 GPCs) can never be placed here.
    pub const A30: GpuClass = GpuClass { name: "a30", gpcs: 4, mem_gb: 24 };

    /// Can this class host `s` at all (profile legality + class capacity)?
    /// Per-GPU feasibility, independent of what is already placed.
    pub fn supports(&self, s: &Slice) -> bool {
        s.is_legal() && s.gpcs <= self.gpcs && s.mem_gb <= self.mem_gb
    }

    /// Parse a class label (`a100` | `a30`).
    pub fn parse(s: &str) -> Option<GpuClass> {
        match s {
            "a100" | "A100" => Some(GpuClass::A100),
            "a30" | "A30" => Some(GpuClass::A30),
            _ => None,
        }
    }
}

impl std::fmt::Display for GpuClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name)
    }
}

/// Parse a fleet spec like `a100x4,a30x2` into an inventory (GPU order
/// follows the spec). A bare class name means one GPU of that class.
pub fn parse_fleet(spec: &str) -> anyhow::Result<Vec<GpuClass>> {
    parse_fleet_with(spec, GpuClass::parse)
}

/// [`parse_fleet`] with a caller-supplied class resolver, so deployments
/// with config-overridden class capacities (`config.cluster` presets)
/// share one spec grammar with the built-in presets.
pub fn parse_fleet_with(
    spec: &str,
    resolve: impl Fn(&str) -> Option<GpuClass>,
) -> anyhow::Result<Vec<GpuClass>> {
    let mut fleet = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, count) = match part.rsplit_once('x') {
            Some((n, c)) if !c.is_empty() && c.chars().all(|ch| ch.is_ascii_digit()) => {
                match c.parse::<usize>() {
                    Ok(k) => (n, k),
                    Err(_) => anyhow::bail!("fleet spec '{part}': count out of range"),
                }
            }
            _ => (part, 1),
        };
        let class = resolve(name)
            .ok_or_else(|| anyhow::anyhow!("unknown GPU class '{name}' (a100|a30)"))?;
        anyhow::ensure!(count >= 1, "fleet spec '{part}': count must be >= 1");
        for _ in 0..count {
            fleet.push(class);
        }
    }
    anyhow::ensure!(!fleet.is_empty(), "empty fleet spec '{spec}'");
    Ok(fleet)
}

/// One MIG instance profile: `<gpcs>g.<mem_gb>gb`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Slice {
    /// GPCs in this instance (compute).
    pub gpcs: usize,
    /// DRAM allocated, GB (also pins the number of L2/DRAM slices).
    pub mem_gb: usize,
}

impl Slice {
    pub const fn new(gpcs: usize, mem_gb: usize) -> Self {
        Slice { gpcs, mem_gb }
    }

    /// The A100-40GB instance profiles NVIDIA exposes (nvidia-smi mig
    /// -lgip): 1g.5gb, 2g.10gb, 3g.20gb, 4g.20gb, 7g.40gb.
    pub const PROFILES: [Slice; 5] = [
        Slice::new(1, 5),
        Slice::new(2, 10),
        Slice::new(3, 20),
        Slice::new(4, 20),
        Slice::new(7, 40),
    ];

    /// Is this a profile the A100 exposes? (e.g. 1 GPC + 20 GB is illegal:
    /// "impossible to combine a single GPC with four L2/DRAM slices".)
    pub fn is_legal(&self) -> bool {
        Slice::PROFILES.contains(self)
    }

    /// Memory-side fraction of the whole GPU this slice owns (DRAM/L2
    /// slices out of 40 GB / 8 slices).
    pub fn mem_frac(&self) -> f64 {
        self.mem_gb as f64 / 40.0
    }

    pub fn name(&self) -> String {
        format!("{}g.{}gb", self.gpcs, self.mem_gb)
    }
}

/// A homogeneous MIG partition: `count` instances of `slice`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Partition {
    pub slice: Slice,
    pub count: usize,
}

/// The three configurations the paper characterizes (§3 footnote 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MigConfig {
    /// 1g.5gb(7x): seven 1-GPC vGPUs.
    Small7,
    /// 2g.10gb(3x): three 2-GPC vGPUs (one GPC is disabled by NVIDIA —
    /// max throughput is 6/7 of the chip).
    Medium3,
    /// 7g.40gb(1x): the unpartitioned GPU.
    Full1,
}

impl MigConfig {
    pub const ALL: [MigConfig; 3] = [MigConfig::Small7, MigConfig::Medium3, MigConfig::Full1];

    pub fn partition(&self) -> Partition {
        match self {
            MigConfig::Small7 => Partition { slice: Slice::new(1, 5), count: 7 },
            MigConfig::Medium3 => Partition { slice: Slice::new(2, 10), count: 3 },
            MigConfig::Full1 => Partition { slice: Slice::new(7, 40), count: 1 },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            MigConfig::Small7 => "1g.5gb(7x)",
            MigConfig::Medium3 => "2g.10gb(3x)",
            MigConfig::Full1 => "7g.40gb(1x)",
        }
    }

    pub fn parse(s: &str) -> Option<MigConfig> {
        match s {
            "1g.5gb(7x)" | "1g" | "7x" | "small" => Some(MigConfig::Small7),
            "2g.10gb(3x)" | "2g" | "3x" | "medium" => Some(MigConfig::Medium3),
            "7g.40gb(1x)" | "7g" | "1x" | "full" => Some(MigConfig::Full1),
            _ => None,
        }
    }

    /// Number of vGPUs.
    pub fn vgpus(&self) -> usize {
        self.partition().count
    }

    /// GPCs per vGPU.
    pub fn gpcs_per_vgpu(&self) -> usize {
        self.partition().slice.gpcs
    }

    /// Total active GPCs (2g.10gb(3x) leaves one GPC dark — paper
    /// footnote 1: max throughput is 14.2% below the others).
    pub fn active_gpcs(&self) -> usize {
        let p = self.partition();
        p.slice.gpcs * p.count
    }
}

impl std::fmt::Display for MigConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl Partition {
    /// Does this partition fit on an A100 (7 GPCs, 40 GB / 8 mem slices)?
    pub fn fits_a100(&self) -> bool {
        self.slice.is_legal()
            && self.slice.gpcs * self.count <= 7
            && self.slice.mem_gb * self.count <= 40
    }

    /// All homogeneous partitions that fit on an A100.
    pub fn all_homogeneous() -> Vec<Partition> {
        let mut out = Vec::new();
        for slice in Slice::PROFILES {
            for count in 1..=7 {
                let p = Partition { slice, count };
                if p.fits_a100() {
                    out.push(p);
                }
            }
        }
        out
    }

    pub fn name(&self) -> String {
        format!("{}({}x)", self.slice.name(), self.count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs_fit() {
        for cfg in MigConfig::ALL {
            assert!(cfg.partition().fits_a100(), "{cfg}");
        }
    }

    #[test]
    fn medium3_leaves_one_gpc_dark() {
        assert_eq!(MigConfig::Medium3.active_gpcs(), 6);
        assert_eq!(MigConfig::Small7.active_gpcs(), 7);
        assert_eq!(MigConfig::Full1.active_gpcs(), 7);
    }

    #[test]
    fn illegal_combinations_rejected() {
        // 1 GPC with 20 GB: explicitly called out as impossible in §2.2.
        assert!(!Slice::new(1, 20).is_legal());
        assert!(!Slice::new(5, 20).is_legal());
        // 2x 7g doesn't fit.
        assert!(!Partition { slice: Slice::new(7, 40), count: 2 }.fits_a100());
        // 8x 1g exceeds 7 GPCs.
        assert!(!Partition { slice: Slice::new(1, 5), count: 8 }.fits_a100());
    }

    #[test]
    fn homogeneous_enumeration_contains_paper_points() {
        let all = Partition::all_homogeneous();
        for cfg in MigConfig::ALL {
            assert!(all.contains(&cfg.partition()), "{cfg}");
        }
        // 3g.20gb can appear at most twice.
        assert!(all.contains(&Partition { slice: Slice::new(3, 20), count: 2 }));
        assert!(!all.contains(&Partition { slice: Slice::new(3, 20), count: 3 }));
    }

    /// The A100 preset is THE consumer of the bare constants; everything
    /// else must go through `GpuClass` (regression guard for the
    /// fleet-wide-capacity cleanup).
    #[test]
    fn a100_class_matches_the_constants() {
        assert_eq!(GpuClass::A100.gpcs, A100_GPCS);
        assert_eq!(GpuClass::A100.mem_gb, A100_MEM_GB);
        assert!(GpuClass::A30.gpcs < GpuClass::A100.gpcs);
    }

    #[test]
    fn class_support_is_per_class() {
        for s in Slice::PROFILES {
            assert!(GpuClass::A100.supports(&s), "{}", s.name());
        }
        assert!(!GpuClass::A30.supports(&Slice::new(7, 40)));
        assert!(GpuClass::A30.supports(&Slice::new(3, 20)));
        assert!(GpuClass::A30.supports(&Slice::new(1, 5)));
        // Illegal profiles are rejected by every class.
        assert!(!GpuClass::A100.supports(&Slice::new(5, 20)));
    }

    #[test]
    fn fleet_specs_parse() {
        let f = parse_fleet("a100x2,a30x3").unwrap();
        assert_eq!(f.len(), 5);
        assert_eq!(f[0], GpuClass::A100);
        assert_eq!(f[2], GpuClass::A30);
        assert_eq!(parse_fleet("a30").unwrap(), vec![GpuClass::A30]);
        assert!(parse_fleet("h100x2").is_err());
        assert!(parse_fleet("").is_err());
        assert!(parse_fleet("a100x0").is_err());
    }

    #[test]
    fn names() {
        assert_eq!(MigConfig::Small7.name(), "1g.5gb(7x)");
        assert_eq!(MigConfig::Small7.partition().name(), "1g.5gb(7x)");
        assert_eq!(MigConfig::parse("2g"), Some(MigConfig::Medium3));
        assert_eq!(MigConfig::parse("bogus"), None);
    }
}
