//! Per-model preprocessing pipeline descriptions (paper Fig 4 / Fig 11).
//!
//! Shared vocabulary between the CPU pool (which charges the whole
//! pipeline to one core) and the DPU (which maps stages onto functional
//! units and pipelines them across CUs).

use crate::models::{ModelId, ModelKind};

/// A preprocessing stage (one functional unit in the DPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StageKind {
    // image (Fig 4a)
    Decode,
    Resize,
    Crop,
    NormalizeImage,
    // audio (Fig 4b)
    Resample,
    MelSpectrogram,
    NormalizeAudio,
}

impl StageKind {
    pub fn name(&self) -> &'static str {
        match self {
            StageKind::Decode => "Decode",
            StageKind::Resize => "Resize",
            StageKind::Crop => "Crop",
            StageKind::NormalizeImage => "Normalize",
            StageKind::Resample => "Resample",
            StageKind::MelSpectrogram => "Mel spectrogram",
            StageKind::NormalizeAudio => "Normalize",
        }
    }

    /// Does this stage need ALL input samples before it can start? (the
    /// audio Normalize global mean/var dependency, paper §4.2 / Fig 12).
    pub fn needs_full_input(&self) -> bool {
        matches!(self, StageKind::NormalizeAudio)
    }
}

/// One stage with its DPU functional-unit latency for a single input.
#[derive(Debug, Clone, Copy)]
pub struct PipelineStage {
    pub kind: StageKind,
    /// Functional-unit time for one request at the reference input size
    /// (2.5 s audio / 224² image), seconds. Calibrated from the Vitis
    /// HLS co-simulation numbers the paper's DPU targets; see DESIGN.md §4.
    pub unit_secs: f64,
}

/// Image pipeline stages (sequential dataflow → one CU integrates all
/// units and pipelines across requests, Fig 12a).
pub const IMAGE_STAGES: [PipelineStage; 4] = [
    PipelineStage { kind: StageKind::Decode, unit_secs: 55e-6 },
    PipelineStage { kind: StageKind::Resize, unit_secs: 30e-6 },
    PipelineStage { kind: StageKind::Crop, unit_secs: 4e-6 },
    PipelineStage { kind: StageKind::NormalizeImage, unit_secs: 18e-6 },
];

/// Audio pipeline stages at the 2.5 s reference length (times scale
/// linearly with audio length).
pub const AUDIO_STAGES: [PipelineStage; 3] = [
    PipelineStage { kind: StageKind::Resample, unit_secs: 20e-6 },
    PipelineStage { kind: StageKind::MelSpectrogram, unit_secs: 330e-6 },
    PipelineStage { kind: StageKind::NormalizeAudio, unit_secs: 45e-6 },
];

/// Pipeline for a model's modality.
pub fn stages_for(model: ModelId) -> &'static [PipelineStage] {
    match model.kind() {
        ModelKind::Vision => &IMAGE_STAGES,
        ModelKind::Audio => &AUDIO_STAGES,
    }
}

/// Stage time for an input of `len_s` seconds (vision ignores length).
pub fn stage_secs(model: ModelId, stage: &PipelineStage, len_s: f64) -> f64 {
    match model.kind() {
        ModelKind::Vision => stage.unit_secs,
        ModelKind::Audio => stage.unit_secs * (len_s / 2.5).max(0.1),
    }
}

/// Total single-request pipeline latency (sum of stages), seconds.
pub fn total_secs(model: ModelId, len_s: f64) -> f64 {
    stages_for(model).iter().map(|s| stage_secs(model, s, len_s)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_pipeline_has_fig4a_stages() {
        let kinds: Vec<StageKind> = IMAGE_STAGES.iter().map(|s| s.kind).collect();
        assert_eq!(
            kinds,
            vec![StageKind::Decode, StageKind::Resize, StageKind::Crop, StageKind::NormalizeImage]
        );
    }

    #[test]
    fn only_audio_normalize_needs_full_input() {
        for s in IMAGE_STAGES {
            assert!(!s.kind.needs_full_input());
        }
        assert!(StageKind::NormalizeAudio.needs_full_input());
        assert!(!StageKind::MelSpectrogram.needs_full_input());
    }

    #[test]
    fn audio_stage_times_scale_with_length() {
        let m = ModelId::CitriNet;
        let t1 = total_secs(m, 2.5);
        let t2 = total_secs(m, 5.0);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn vision_length_ignored() {
        let m = ModelId::MobileNet;
        assert_eq!(total_secs(m, 0.0), total_secs(m, 10.0));
    }

    #[test]
    fn single_input_latency_is_sub_millisecond() {
        // The DPU is latency-optimized: single-request preprocessing must
        // be far below the ~ms model-execution times (paper §4.2).
        assert!(total_secs(ModelId::MobileNet, 0.0) < 150e-6);
        assert!(total_secs(ModelId::CitriNet, 2.5) < 500e-6);
    }
}
