//! Data preprocessing: the stage the paper identifies as MIG's bottleneck
//! (§3.3) and the one PREBA offloads to the DPU.
//!
//! Three parts:
//! * [`ops`] — *real* Rust implementations of the full pipelines the paper
//!   runs with OpenCV/Librosa (image: dequantize + 8×8 IDCT decode,
//!   bilinear resize, crop, normalize; audio: linear resample, Hann
//!   window + DFT magnitude, mel filterbank, log, global mean/var
//!   normalize). The real-PJRT driver runs these on the host for the
//!   CPU-baseline path and validates them against the Pallas kernels'
//!   pure-jnp oracles via golden vectors.
//! * [`cpu_pool`] — the host-CPU contention model used by the DES: a
//!   c-server queue over `cpu_cores - reserved` cores with per-model
//!   service times from the calibration table, reproducing Fig 8/9.
//! * [`pipeline`] — per-model pipeline descriptions shared by the CPU path
//!   and the DPU (stage names/costs mirror Fig 4 / Fig 11).

pub mod cpu_pool;
pub mod ops;
pub mod pipeline;

pub use cpu_pool::CpuPool;
pub use pipeline::{PipelineStage, StageKind};
