//! Real preprocessing implementations (the compute the paper's baseline
//! runs with OpenCV / Librosa, and PREBA offloads to the DPU).
//!
//! These mirror the Pallas kernels in `python/compile/kernels/` operation
//! for operation so the CPU path and the DPU path produce the same
//! tensors; `rust/tests/integration_runtime.rs` cross-checks them against
//! the kernels' lowered HLO executed on PJRT (which pytest in turn pins
//! to the pure-jnp oracle `ref.py`).
//!
//! Image pipeline (paper Fig 4a): decode (dequantize + 8×8 IDCT — the
//! compute core of JPEG decoding; entropy decode is control flow and is
//! cost-modeled, see DESIGN.md §Hardware-Adaptation) → bilinear resize →
//! center crop → per-channel normalize.
//!
//! Audio pipeline (paper Fig 4b): linear resample → Hann-windowed framing
//! → DFT magnitude (matmul form) → mel filterbank → log → global
//! mean/variance normalize.

use std::f32::consts::PI;

use once_cell::sync::Lazy;

// ---------------------------------------------------------------------------
// §Perf: precomputed tables (EXPERIMENTS.md §Perf, L3 iteration log).
// The audio pipeline previously recomputed the 512x257 cos/sin DFT bases
// (~263k transcendental evals) and the mel filterbank on EVERY request;
// the image pipeline rebuilt the resize matrices per call. Caching these
// and exploiting their sparsity is the single largest hot-path win
// (audio 24.1 ms -> see EXPERIMENTS.md; exactness is unchanged — the
// same values are computed once instead of per call).
// ---------------------------------------------------------------------------

static DFT_BASES_512: Lazy<(Vec<f32>, Vec<f32>)> = Lazy::new(|| dft_bases(512));
static MEL_FB_80_512: Lazy<Vec<f32>> = Lazy::new(|| mel_filterbank(80, 512, 16000.0));
static HANN_512: Lazy<Vec<f32>> = Lazy::new(|| hann(512));

/// Raw (cos, -sin) DFT bases, (n_bins x n_fft) row-major each.
pub fn dft_bases(n_fft: usize) -> (Vec<f32>, Vec<f32>) {
    let n_bins = n_fft / 2 + 1;
    let mut cos_b = vec![0f32; n_bins * n_fft];
    let mut sin_b = vec![0f32; n_bins * n_fft];
    for k in 0..n_bins {
        for n in 0..n_fft {
            let ang = 2.0 * PI * (k * n) as f32 / n_fft as f32;
            cos_b[k * n_fft + n] = ang.cos();
            sin_b[k * n_fft + n] = -ang.sin();
        }
    }
    (cos_b, sin_b)
}

/// Sparse form of a bilinear resize matrix: per output index, the two
/// source taps `(i0, i1, frac)` with `w0 = 1-frac`, `w1 = frac`. Exactly
/// equivalent to the dense matrix (it has <= 2 nonzeros per row by
/// construction).
fn resize_taps(src: usize, dst: usize) -> Vec<(usize, usize, f32)> {
    let scale = src as f64 / dst as f64;
    (0..dst)
        .map(|d| {
            let pos = (d as f64 + 0.5) * scale - 0.5;
            let lo = pos.floor();
            let frac = (pos - lo) as f32;
            let i0 = (lo as isize).clamp(0, src as isize - 1) as usize;
            let i1 = (lo as isize + 1).clamp(0, src as isize - 1) as usize;
            (i0, i1, frac)
        })
        .collect()
}

// --------------------------------------------------------------------------
// Image ops
// --------------------------------------------------------------------------

/// The JPEG luma quantization table (Annex K) scaled by quality 75 — used
/// as the reference dequantization table for the decode stage.
pub fn jpeg_quant_table() -> [f32; 64] {
    const BASE: [u16; 64] = [
        16, 11, 10, 16, 24, 40, 51, 61, 12, 12, 14, 19, 26, 58, 60, 55, 14, 13, 16, 24, 40, 57,
        69, 56, 14, 17, 22, 29, 51, 87, 80, 62, 18, 22, 37, 56, 68, 109, 103, 77, 24, 35, 55, 64,
        81, 104, 113, 92, 49, 64, 78, 87, 103, 121, 120, 101, 72, 92, 95, 98, 112, 100, 103, 99,
    ];
    // quality 75 -> scale = 200 - 2*75 = 50 (%).
    let mut out = [0f32; 64];
    for i in 0..64 {
        out[i] = ((BASE[i] as f32 * 50.0 / 100.0).floor()).max(1.0);
    }
    out
}

/// 8×8 inverse DCT-II basis matrix `C` such that `pixels = C^T * X * C`
/// for a coefficient block `X` (row-major 8×8).
pub fn idct8_basis() -> [f32; 64] {
    let mut c = [0f32; 64];
    for k in 0..8 {
        let a = if k == 0 { (1.0f32 / 8.0).sqrt() } else { (2.0f32 / 8.0).sqrt() };
        for n in 0..8 {
            c[k * 8 + n] = a * ((PI / 8.0) * (n as f32 + 0.5) * k as f32).cos();
        }
    }
    c
}

/// Decode one image: per-8×8-block dequantize + 2-D IDCT, then +128 shift.
///
/// `coeffs` is HWC with H, W multiples of 8 holding quantized DCT
/// coefficients per channel; output is pixel-domain HWC in [0, 255]-ish
/// (not clamped — matches the jnp reference).
pub fn decode_blocks(coeffs: &[f32], h: usize, w: usize, ch: usize) -> Vec<f32> {
    assert_eq!(coeffs.len(), h * w * ch);
    assert!(h % 8 == 0 && w % 8 == 0, "decode needs 8-aligned dims");
    let q = jpeg_quant_table();
    let c = idct8_basis();
    let mut out = vec![0f32; coeffs.len()];
    let mut x = [0f32; 64];
    let mut tmp = [0f32; 64];
    for by in (0..h).step_by(8) {
        for bx in (0..w).step_by(8) {
            for cc in 0..ch {
                // Gather + dequantize the block.
                for i in 0..8 {
                    for j in 0..8 {
                        x[i * 8 + j] = coeffs[((by + i) * w + bx + j) * ch + cc] * q[i * 8 + j];
                    }
                }
                // tmp = C^T * X  (tmp[i][j] = sum_k C[k][i] * X[k][j])
                for i in 0..8 {
                    for j in 0..8 {
                        let mut s = 0f32;
                        for k in 0..8 {
                            s += c[k * 8 + i] * x[k * 8 + j];
                        }
                        tmp[i * 8 + j] = s;
                    }
                }
                // out = tmp * C  (out[i][j] = sum_k tmp[i][k] * C[k][j])
                for i in 0..8 {
                    for j in 0..8 {
                        let mut s = 0f32;
                        for k in 0..8 {
                            s += tmp[i * 8 + k] * c[k * 8 + j];
                        }
                        out[((by + i) * w + bx + j) * ch + cc] = s + 128.0;
                    }
                }
            }
        }
    }
    out
}

/// Row/column interpolation matrix for separable bilinear resize from
/// `src` to `dst` samples (align_corners=false, half-pixel centers —
/// matches `jax.image.resize(method="linear")`).
pub fn resize_matrix(src: usize, dst: usize) -> Vec<f32> {
    let mut m = vec![0f32; dst * src];
    let scale = src as f64 / dst as f64;
    for d in 0..dst {
        let pos = (d as f64 + 0.5) * scale - 0.5;
        let lo = pos.floor();
        let frac = (pos - lo) as f32;
        let i0 = (lo as isize).clamp(0, src as isize - 1) as usize;
        let i1 = (lo as isize + 1).clamp(0, src as isize - 1) as usize;
        m[d * src + i0] += 1.0 - frac;
        m[d * src + i1] += frac;
    }
    m
}

/// Separable bilinear resize of an HWC image: rows then columns.
///
/// §Perf: evaluated in sparse two-tap form rather than dense matmul —
/// O(out * 2) instead of O(out * src) — numerically identical to the
/// dense matrix (<= 2 nonzeros per row; `tests::resize_*` pin this).
pub fn resize_bilinear(
    img: &[f32],
    h: usize,
    w: usize,
    ch: usize,
    oh: usize,
    ow: usize,
) -> Vec<f32> {
    assert_eq!(img.len(), h * w * ch);
    let row_taps = resize_taps(h, oh);
    let col_taps = resize_taps(w, ow);
    // rows: tmp[oy][x][c] = (1-f)*img[y0][x][c] + f*img[y1][x][c]
    let mut tmp = vec![0f32; oh * w * ch];
    for (oy, &(y0, y1, f)) in row_taps.iter().enumerate() {
        let (w0, w1) = (1.0 - f, f);
        let src0 = &img[y0 * w * ch..(y0 + 1) * w * ch];
        let src1 = &img[y1 * w * ch..(y1 + 1) * w * ch];
        let dst = &mut tmp[oy * w * ch..(oy + 1) * w * ch];
        for ((d, a), b) in dst.iter_mut().zip(src0.iter()).zip(src1.iter()) {
            *d = w0 * a + w1 * b;
        }
    }
    // cols: out[oy][ox][c] = (1-f)*tmp[oy][x0][c] + f*tmp[oy][x1][c]
    let mut out = vec![0f32; oh * ow * ch];
    for oy in 0..oh {
        let row = &tmp[oy * w * ch..(oy + 1) * w * ch];
        let orow = &mut out[oy * ow * ch..(oy + 1) * ow * ch];
        for (ox, &(x0, x1, f)) in col_taps.iter().enumerate() {
            let (w0, w1) = (1.0 - f, f);
            for cc in 0..ch {
                orow[ox * ch + cc] = w0 * row[x0 * ch + cc] + w1 * row[x1 * ch + cc];
            }
        }
    }
    out
}

/// Center crop an HWC image to `(ch_h, ch_w)`.
pub fn center_crop(img: &[f32], h: usize, w: usize, ch: usize, oh: usize, ow: usize) -> Vec<f32> {
    assert!(oh <= h && ow <= w);
    let y0 = (h - oh) / 2;
    let x0 = (w - ow) / 2;
    let mut out = vec![0f32; oh * ow * ch];
    for y in 0..oh {
        for x in 0..ow {
            for cc in 0..ch {
                out[(y * ow + x) * ch + cc] = img[((y0 + y) * w + x0 + x) * ch + cc];
            }
        }
    }
    out
}

/// ImageNet per-channel normalization of a [0,255] HWC image.
pub fn normalize_image(img: &mut [f32], ch: usize, mean: &[f32], std: &[f32]) {
    assert_eq!(mean.len(), ch);
    assert_eq!(std.len(), ch);
    for px in img.chunks_exact_mut(ch) {
        for (cc, v) in px.iter_mut().enumerate() {
            *v = (*v / 255.0 - mean[cc]) / std[cc];
        }
    }
}

/// Full image pipeline: decode -> resize -> crop -> normalize.
/// Input: quantized DCT coefficient image (src_h × src_w × ch).
pub fn image_pipeline(
    coeffs: &[f32],
    src_h: usize,
    src_w: usize,
    ch: usize,
    resize_to: usize,
    crop_to: usize,
) -> Vec<f32> {
    let decoded = decode_blocks(coeffs, src_h, src_w, ch);
    let resized = resize_bilinear(&decoded, src_h, src_w, ch, resize_to, resize_to);
    let mut cropped = center_crop(&resized, resize_to, resize_to, ch, crop_to, crop_to);
    normalize_image(&mut cropped, ch, &[0.485, 0.456, 0.406], &[0.229, 0.224, 0.225]);
    cropped
}

// --------------------------------------------------------------------------
// Audio ops
// --------------------------------------------------------------------------

/// Linear-interpolation resample from `src_rate` to `dst_rate` Hz.
pub fn resample_linear(x: &[f32], src_rate: u32, dst_rate: u32) -> Vec<f32> {
    if src_rate == dst_rate {
        return x.to_vec();
    }
    let n_out = (x.len() as u64 * dst_rate as u64 / src_rate as u64) as usize;
    let ratio = src_rate as f64 / dst_rate as f64;
    let mut out = Vec::with_capacity(n_out);
    for i in 0..n_out {
        let pos = i as f64 * ratio;
        let lo = pos.floor() as usize;
        let frac = (pos - lo as f64) as f32;
        let a = x[lo.min(x.len() - 1)];
        let b = x[(lo + 1).min(x.len() - 1)];
        out.push(a + frac * (b - a));
    }
    out
}

/// Hann window of length `n` (periodic, matching jnp.hanning-style
/// symmetric window used by the reference: we use symmetric).
pub fn hann(n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| {
            if n == 1 {
                1.0
            } else {
                0.5 - 0.5 * ((2.0 * PI * i as f32) / (n as f32 - 1.0)).cos()
            }
        })
        .collect()
}

/// Power spectrogram via direct DFT (matmul form — mirrors the MXU
/// adaptation in the Pallas kernel): frames of `n_fft` with hop `hop`,
/// Hann window, returns `(n_frames, n_fft/2 + 1)` row-major power values.
pub fn power_spectrogram(x: &[f32], n_fft: usize, hop: usize) -> (Vec<f32>, usize, usize) {
    assert!(x.len() >= n_fft, "input shorter than one frame");
    let n_frames = 1 + (x.len() - n_fft) / hop;
    let n_bins = n_fft / 2 + 1;
    // §Perf: the standard 512-point configuration reuses cached tables.
    let (cos_owned, sin_owned);
    let (cos_b, sin_b, win): (&[f32], &[f32], &[f32]) = if n_fft == 512 {
        (&DFT_BASES_512.0, &DFT_BASES_512.1, &HANN_512)
    } else {
        let (c, s) = dft_bases(n_fft);
        cos_owned = c;
        sin_owned = s;
        (&cos_owned, &sin_owned, &[])
    };
    let win_owned;
    let win: &[f32] = if win.is_empty() {
        win_owned = hann(n_fft);
        &win_owned
    } else {
        win
    };
    // §Perf: frames are processed in blocks of FB so each basis row
    // (4 KiB) is read once per FB frames instead of once per frame — the
    // kernel is bandwidth-bound on the 1 MiB basis matrices otherwise.
    const FB: usize = 8;
    let mut out = vec![0f32; n_frames * n_bins];
    let mut frames = vec![0f32; FB * n_fft];
    let mut f0 = 0;
    while f0 < n_frames {
        let fb_n = FB.min(n_frames - f0);
        for (fi, frame) in frames.chunks_exact_mut(n_fft).take(fb_n).enumerate() {
            let start = (f0 + fi) * hop;
            for n in 0..n_fft {
                frame[n] = x[start + n] * win[n];
            }
        }
        for k in 0..n_bins {
            let cb = &cos_b[k * n_fft..(k + 1) * n_fft];
            let sb = &sin_b[k * n_fft..(k + 1) * n_fft];
            for fi in 0..fb_n {
                let frame = &frames[fi * n_fft..(fi + 1) * n_fft];
                let mut re = 0f32;
                let mut im = 0f32;
                // §Perf: four independent accumulators per dot product
                // break the serial f32 add dependency chain (the scalar
                // version ran at ~1.7 GFLOP/s, bound by add latency).
                let (mut re0, mut re1, mut re2, mut re3) = (0f32, 0f32, 0f32, 0f32);
                let (mut im0, mut im1, mut im2, mut im3) = (0f32, 0f32, 0f32, 0f32);
                let mut n = 0;
                while n + 4 <= n_fft {
                    re0 += frame[n] * cb[n];
                    re1 += frame[n + 1] * cb[n + 1];
                    re2 += frame[n + 2] * cb[n + 2];
                    re3 += frame[n + 3] * cb[n + 3];
                    im0 += frame[n] * sb[n];
                    im1 += frame[n + 1] * sb[n + 1];
                    im2 += frame[n + 2] * sb[n + 2];
                    im3 += frame[n + 3] * sb[n + 3];
                    n += 4;
                }
                re += (re0 + re1) + (re2 + re3);
                im += (im0 + im1) + (im2 + im3);
                while n < n_fft {
                    re += frame[n] * cb[n];
                    im += frame[n] * sb[n];
                    n += 1;
                }
                out[(f0 + fi) * n_bins + k] = re * re + im * im;
            }
        }
        f0 += fb_n;
    }
    (out, n_frames, n_bins)
}

/// Hz -> mel (Slaney-style HTK formula, matching librosa htk=True and the
/// jnp reference).
pub fn hz_to_mel(hz: f32) -> f32 {
    2595.0 * (1.0 + hz / 700.0).log10()
}

pub fn mel_to_hz(mel: f32) -> f32 {
    700.0 * (10f32.powf(mel / 2595.0) - 1.0)
}

/// Triangular mel filterbank: `(n_mels, n_bins)` row-major.
pub fn mel_filterbank(n_mels: usize, n_fft: usize, sample_rate: f32) -> Vec<f32> {
    let n_bins = n_fft / 2 + 1;
    let f_min = 0.0f32;
    let f_max = sample_rate / 2.0;
    let m_min = hz_to_mel(f_min);
    let m_max = hz_to_mel(f_max);
    // n_mels + 2 edge points.
    let edges: Vec<f32> = (0..n_mels + 2)
        .map(|i| mel_to_hz(m_min + (m_max - m_min) * i as f32 / (n_mels + 1) as f32))
        .collect();
    let bin_hz: Vec<f32> = (0..n_bins).map(|k| k as f32 * sample_rate / n_fft as f32).collect();
    let mut fb = vec![0f32; n_mels * n_bins];
    for m in 0..n_mels {
        let (lo, ctr, hi) = (edges[m], edges[m + 1], edges[m + 2]);
        for k in 0..n_bins {
            let f = bin_hz[k];
            let w = if f <= lo || f >= hi {
                0.0
            } else if f <= ctr {
                (f - lo) / (ctr - lo)
            } else {
                (hi - f) / (hi - ctr)
            };
            fb[m * n_bins + k] = w;
        }
    }
    fb
}

/// Log-mel spectrogram: power spectrogram × mel filterbank, then
/// `ln(x + eps)`. Returns `(n_frames, n_mels)` row-major.
pub fn log_mel(
    x: &[f32],
    n_fft: usize,
    hop: usize,
    n_mels: usize,
    sample_rate: f32,
) -> (Vec<f32>, usize, usize) {
    let (spec, n_frames, n_bins) = power_spectrogram(x, n_fft, hop);
    // §Perf: cached filterbank for the standard config + sparse ranges
    // (each triangular filter touches a contiguous ~10-40 bin span).
    let fb_owned;
    let fb: &[f32] = if (n_mels, n_fft, sample_rate) == (80, 512, 16000.0) {
        &MEL_FB_80_512
    } else {
        fb_owned = mel_filterbank(n_mels, n_fft, sample_rate);
        &fb_owned
    };
    let ranges: Vec<(usize, usize)> = (0..n_mels)
        .map(|m| {
            let row = &fb[m * n_bins..(m + 1) * n_bins];
            let lo = row.iter().position(|&v| v != 0.0).unwrap_or(0);
            let hi = n_bins - row.iter().rev().position(|&v| v != 0.0).unwrap_or(n_bins - lo);
            (lo, hi)
        })
        .collect();
    let mut out = vec![0f32; n_frames * n_mels];
    for f in 0..n_frames {
        let srow = &spec[f * n_bins..(f + 1) * n_bins];
        for (m, &(lo, hi)) in ranges.iter().enumerate() {
            let frow = &fb[m * n_bins..(m + 1) * n_bins];
            let mut s = 0f32;
            for k in lo..hi {
                s += srow[k] * frow[k];
            }
            out[f * n_mels + m] = (s + 1e-3).ln();
        }
    }
    (out, n_frames, n_mels)
}

/// Global per-feature mean/variance normalization over the time axis —
/// the stage whose all-samples dependency forces the DPU's split-CU design
/// (paper Fig 12).
pub fn normalize_features(feat: &mut [f32], n_frames: usize, n_feat: usize) {
    assert_eq!(feat.len(), n_frames * n_feat);
    for m in 0..n_feat {
        let mut mean = 0f64;
        for f in 0..n_frames {
            mean += feat[f * n_feat + m] as f64;
        }
        mean /= n_frames as f64;
        let mut var = 0f64;
        for f in 0..n_frames {
            let d = feat[f * n_feat + m] as f64 - mean;
            var += d * d;
        }
        var /= n_frames as f64;
        let inv = 1.0 / (var + 1e-2).sqrt();
        for f in 0..n_frames {
            feat[f * n_feat + m] = ((feat[f * n_feat + m] as f64 - mean) * inv) as f32;
        }
    }
}

/// Full audio pipeline: resample -> log-mel -> normalize. Returns
/// `(features, n_frames, n_mels)`.
pub fn audio_pipeline(
    pcm: &[f32],
    src_rate: u32,
    n_fft: usize,
    hop: usize,
    n_mels: usize,
) -> (Vec<f32>, usize, usize) {
    const TARGET_RATE: u32 = 16_000;
    let resampled = resample_linear(pcm, src_rate, TARGET_RATE);
    let (mut feat, n_frames, nm) = log_mel(&resampled, n_fft, hop, n_mels, TARGET_RATE as f32);
    normalize_features(&mut feat, n_frames, nm);
    (feat, n_frames, nm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idct_basis_is_orthonormal() {
        let c = idct8_basis();
        for i in 0..8 {
            for j in 0..8 {
                let dot: f32 = (0..8).map(|n| c[i * 8 + n] * c[j * 8 + n]).sum();
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-5, "({i},{j}) dot={dot}");
            }
        }
    }

    #[test]
    fn decode_dc_only_block_is_flat() {
        // A block with only a DC coefficient decodes to a constant.
        let mut coeffs = vec![0f32; 8 * 8 * 1];
        coeffs[0] = 10.0; // DC, will be dequantized by q[0]=8
        let px = decode_blocks(&coeffs, 8, 8, 1);
        let first = px[0];
        assert!(px.iter().all(|&v| (v - first).abs() < 1e-4));
        // DC=10 * q0(=floor(16*0.5)=8) / 8 + 128 = 138
        assert!((first - 138.0).abs() < 1e-3, "first={first}");
    }

    #[test]
    fn resize_matrix_rows_sum_to_one() {
        for (src, dst) in [(96, 64), (64, 96), (50, 50), (7, 13)] {
            let m = resize_matrix(src, dst);
            for d in 0..dst {
                let s: f32 = m[d * src..(d + 1) * src].iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "src={src} dst={dst} row={d} sum={s}");
            }
        }
    }

    #[test]
    fn resize_constant_image_stays_constant() {
        let img = vec![3.5f32; 32 * 48 * 3];
        let out = resize_bilinear(&img, 32, 48, 3, 20, 24);
        assert!(out.iter().all(|&v| (v - 3.5).abs() < 1e-5));
    }

    #[test]
    fn identity_resize_preserves() {
        let img: Vec<f32> = (0..16 * 16 * 1).map(|i| i as f32).collect();
        let out = resize_bilinear(&img, 16, 16, 1, 16, 16);
        for (a, b) in img.iter().zip(out.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn crop_takes_center() {
        // 4x4 single-channel, crop to 2x2 takes rows/cols 1..3.
        let img: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let out = center_crop(&img, 4, 4, 1, 2, 2);
        assert_eq!(out, vec![5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn normalize_image_zero_mean_for_mid_gray() {
        let mut img = vec![127.5f32; 4 * 3];
        normalize_image(&mut img, 3, &[0.5, 0.5, 0.5], &[0.25, 0.25, 0.25]);
        assert!(img.iter().all(|&v| v.abs() < 1e-5));
    }

    #[test]
    fn full_image_pipeline_shapes() {
        let coeffs = vec![1f32; 96 * 96 * 3];
        let out = image_pipeline(&coeffs, 96, 96, 3, 72, 64);
        assert_eq!(out.len(), 64 * 64 * 3);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn resample_preserves_constant_and_length_ratio() {
        let x = vec![2.0f32; 8000];
        let y = resample_linear(&x, 8000, 16000);
        assert_eq!(y.len(), 16000);
        assert!(y.iter().all(|&v| (v - 2.0).abs() < 1e-6));
        let z = resample_linear(&x, 8000, 8000);
        assert_eq!(z.len(), x.len());
    }

    #[test]
    fn spectrogram_peak_at_tone_frequency() {
        // 1 kHz tone at 16 kHz, n_fft=512 -> bin 32.
        let sr = 16000f32;
        let x: Vec<f32> =
            (0..4096).map(|i| (2.0 * PI * 1000.0 * i as f32 / sr).sin()).collect();
        let (spec, n_frames, n_bins) = power_spectrogram(&x, 512, 256);
        assert_eq!(n_bins, 257);
        // Peak bin in the middle frame:
        let f = n_frames / 2;
        let row = &spec[f * n_bins..(f + 1) * n_bins];
        let peak = row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(peak, 32, "peak at bin {peak}");
    }

    #[test]
    fn mel_filterbank_covers_spectrum() {
        let fb = mel_filterbank(80, 512, 16000.0);
        // Every filter has some mass; interior bins are covered.
        for m in 0..80 {
            let s: f32 = fb[m * 257..(m + 1) * 257].iter().sum();
            assert!(s > 0.0, "mel filter {m} empty");
        }
    }

    #[test]
    fn hz_mel_roundtrip() {
        for hz in [100.0, 440.0, 1000.0, 7999.0] {
            assert!((mel_to_hz(hz_to_mel(hz)) - hz).abs() / hz < 1e-4);
        }
    }

    #[test]
    fn normalize_features_zero_mean_unit_var() {
        let mut rng = crate::util::Rng::new(3);
        let (nf, nm) = (100, 8);
        let mut feat: Vec<f32> = (0..nf * nm).map(|_| rng.f64() as f32 * 10.0).collect();
        normalize_features(&mut feat, nf, nm);
        for m in 0..nm {
            let mean: f32 = (0..nf).map(|f| feat[f * nm + m]).sum::<f32>() / nf as f32;
            let var: f32 =
                (0..nf).map(|f| (feat[f * nm + m] - mean).powi(2)).sum::<f32>() / nf as f32;
            assert!(mean.abs() < 1e-4, "mel {m} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "mel {m} var {var}");
        }
    }

    #[test]
    fn full_audio_pipeline_shapes() {
        let pcm: Vec<f32> = (0..16000).map(|i| (i as f32 * 0.01).sin()).collect();
        let (feat, n_frames, n_mels) = audio_pipeline(&pcm, 16000, 512, 256, 80);
        assert_eq!(n_mels, 80);
        assert_eq!(feat.len(), n_frames * n_mels);
        assert!(feat.iter().all(|v| v.is_finite()));
    }
}
