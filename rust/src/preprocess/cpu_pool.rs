//! Host-CPU preprocessing pool with core contention (paper §3.3, Fig 8/9).
//!
//! Models the baseline: each request's preprocessing occupies one core for
//! the model's calibrated per-input CPU time. With `cpu_cores - reserved`
//! cores and demand of `qps × cpu_secs` core-seconds per second, the pool
//! saturates exactly the way Fig 9 shows (utilization ~90% with only a few
//! inference servers active, throughput flat beyond).
//!
//! Implemented as a c-server FIFO queue inside the DES: `admit` returns
//! the completion time for a request, tracking per-core busy-until times.

use crate::clock::{secs, Nanos};
use crate::util::Rng;

/// Relative jitter (lognormal sigma) on CPU preprocessing times.
const CPU_JITTER_SIGMA: f64 = 0.10;

/// A pool of identical cores serving preprocessing jobs FIFO.
#[derive(Debug)]
pub struct CpuPool {
    /// busy-until time per core.
    cores: Vec<Nanos>,
    /// Busy core-nanoseconds accumulated (for utilization).
    busy_ns: u128,
    /// Jobs served.
    pub served: u64,
    rng: Rng,
}

impl CpuPool {
    /// `n` usable cores (already minus the serving-reserved ones).
    pub fn new(n: usize, rng: Rng) -> CpuPool {
        assert!(n > 0);
        CpuPool { cores: vec![0; n], busy_ns: 0, served: 0, rng }
    }

    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    /// Admit a job arriving at `now` needing `service_secs` of one core.
    /// Returns (start, completion) times under FIFO earliest-core
    /// assignment.
    pub fn admit(&mut self, now: Nanos, service_secs: f64) -> (Nanos, Nanos) {
        let jitter = self.rng.lognormal(0.0, CPU_JITTER_SIGMA);
        let service = secs(service_secs * jitter);
        // Earliest-available core.
        let (idx, &free_at) =
            self.cores.iter().enumerate().min_by_key(|(_, &t)| t).expect("non-empty pool");
        let start = now.max(free_at);
        let done = start + service;
        self.cores[idx] = done;
        self.busy_ns += service as u128;
        self.served += 1;
        (start, done)
    }

    /// Pool utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: Nanos) -> f64 {
        if horizon == 0 {
            return 0.0;
        }
        // A core can be "busy" past the horizon if jobs queued up; clamp
        // to 1.0 — real utilization cannot exceed the pool.
        (self.busy_ns as f64 / (horizon as f64 * self.cores.len() as f64)).min(1.0)
    }

    /// Max sustainable throughput for jobs of `service_secs`, jobs/s.
    pub fn capacity_qps(&self, service_secs: f64) -> f64 {
        self.cores.len() as f64 / service_secs
    }

    /// Current backlog depth proxy: how far the most-loaded core's
    /// busy-until exceeds `now` (seconds).
    pub fn backlog_secs(&self, now: Nanos) -> f64 {
        let max_busy = self.cores.iter().copied().max().unwrap_or(0);
        (max_busy.saturating_sub(now)) as f64 * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{millis, to_secs};

    fn pool(n: usize) -> CpuPool {
        CpuPool::new(n, Rng::new(7))
    }

    #[test]
    fn single_core_serializes() {
        let mut p = pool(1);
        let (s1, d1) = p.admit(0, 0.010);
        let (s2, d2) = p.admit(0, 0.010);
        assert_eq!(s1, 0);
        assert_eq!(s2, d1, "second job waits for first");
        assert!(d2 > d1);
    }

    #[test]
    fn parallel_cores_run_concurrently() {
        let mut p = pool(4);
        let dones: Vec<Nanos> = (0..4).map(|_| p.admit(0, 0.010).1).collect();
        // All four run in parallel: completions within jitter (~±30%).
        let max = *dones.iter().max().unwrap() as f64;
        let min = *dones.iter().min().unwrap() as f64;
        assert!(max / min < 1.6, "not parallel: {dones:?}");
    }

    #[test]
    fn saturation_throughput_matches_capacity() {
        // Offer 2x the capacity and check served throughput ~= capacity.
        let mut p = pool(8);
        let service = 0.010; // 10 ms
        let cap = p.capacity_qps(service); // 800/s
        let offered = cap * 2.0;
        let dt = secs(1.0 / offered);
        let mut last_done = 0;
        let n = 4000;
        for i in 0..n {
            let (_, done) = p.admit(i as Nanos * dt, service);
            last_done = last_done.max(done);
        }
        let achieved = n as f64 / to_secs(last_done);
        assert!((achieved / cap - 1.0).abs() < 0.05, "achieved={achieved} cap={cap}");
    }

    #[test]
    fn utilization_tracks_load() {
        // 50% load: 100 jobs x 10 ms over 2 s on ONE core = 1 s busy
        // out of 2 core-seconds.
        let mut p = pool(1);
        for i in 0..100 {
            p.admit(millis(i as f64 * 20.0), 0.010);
        }
        let u = p.utilization(secs(2.0));
        assert!((u - 0.5).abs() < 0.1, "u={u}");
    }

    #[test]
    fn backlog_grows_under_overload() {
        let mut p = pool(1);
        for _ in 0..100 {
            p.admit(0, 0.010);
        }
        assert!(p.backlog_secs(0) > 0.9);
    }
}
