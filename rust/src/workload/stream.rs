//! Pull-based arrival streams: the lazy seam between workload synthesis
//! and the DES drivers.
//!
//! Every arrival source in the crate used to materialize a full
//! `Vec<Arrival>` up front and the drivers scheduled the whole workload
//! into the event heap before the first pop. That caps trace scale at
//! whatever fits in memory twice (the trace plus the heap). This module
//! inverts the flow: a driver *pulls* arrivals one at a time through
//! [`ArrivalStream`] and injects them into the simulation as virtual time
//! reaches them, so the heap only ever holds in-flight work and the
//! source only ever holds a bounded read window.
//!
//! Three layers:
//!
//! * [`ArrivalStream`] — `next_arrival()` plus rate/duration hints.
//!   Implemented by [`QueryGen`], [`TraceGen`], [`ReplayCursor`] (a
//!   cursor over a materialized [`ReplayTrace`]), and [`Bounded`].
//! * [`TimestampStream`] — a bare monotone `f64`-seconds source:
//!   [`SynthAzure`] (the deterministic Azure-shaped generator, usable at
//!   multi-million-row scale without materializing), plus chunked
//!   [`CsvTraceReader`]/[`JsonTraceReader`] file readers and the
//!   [`ScaleTs`]/[`ThinTs`] rescaling adapters. [`WithLengths`] lifts a
//!   timestamp stream to an [`ArrivalStream`] by sampling per-request
//!   input lengths.
//! * [`StreamSpec`] — a cloneable, `Send + Sync` *description* of a
//!   stream (source + rescale knobs) that `ClusterTenant` can carry;
//!   the driver opens one live stream per tenant per run.
//!
//! Determinism contract: for the same seed, a stream yields bit-identical
//! arrivals to the eager path it replaces ([`ReplayTrace::arrivals`],
//! `QueryGen::take`, `ReplayTrace::synth_azure` + `rescaled`), which is
//! what lets `tests/prop_stream.rs` demand byte-identical
//! `ClusterOutcome`s across the two paths.

use std::fs::File;
use std::io::{BufRead, BufReader};

use crate::clock::secs;
use crate::models::{ModelId, ModelKind};
use crate::util::Rng;

use super::trace::ReplayTrace;
use super::{sample_librispeech_len, Arrival, QueryGen, TraceGen};

/// A pull-based arrival source. Arrivals must be yielded in
/// non-decreasing `at` order; a `None` is final (streams are fused).
pub trait ArrivalStream {
    /// The next arrival, or `None` when the stream is exhausted.
    /// Infinite processes (Poisson, MMPP) never return `None`; wrap them
    /// in [`Bounded`] before handing them to a driver.
    fn next_arrival(&mut self) -> Option<Arrival>;

    /// Long-run mean offered rate, queries/s, if the source knows it.
    fn rate_hint(&self) -> Option<f64> {
        None
    }

    /// Total span of the stream in seconds, if finite and known.
    fn duration_hint_s(&self) -> Option<f64> {
        None
    }

    /// Check that the backing source still matches what the stream was
    /// opened against. In-memory and synthetic sources are trivially
    /// stable (the default); file-backed sources re-scan the file and
    /// fail if it mutated between the sizing probe and the end of replay
    /// (see [`SourceGuard`]). Drivers call this once, after the event
    /// loop drains.
    fn verify_source(&self) -> anyhow::Result<()> {
        Ok(())
    }
}

impl ArrivalStream for QueryGen {
    fn next_arrival(&mut self) -> Option<Arrival> {
        Some(self.next())
    }

    fn rate_hint(&self) -> Option<f64> {
        Some(self.rate())
    }
}

impl ArrivalStream for TraceGen {
    fn next_arrival(&mut self) -> Option<Arrival> {
        Some(self.next())
    }

    fn rate_hint(&self) -> Option<f64> {
        Some(self.profile().mean_rate())
    }
}

impl ArrivalStream for Box<dyn ArrivalStream> {
    fn next_arrival(&mut self) -> Option<Arrival> {
        (**self).next_arrival()
    }

    fn rate_hint(&self) -> Option<f64> {
        (**self).rate_hint()
    }

    fn duration_hint_s(&self) -> Option<f64> {
        (**self).duration_hint_s()
    }

    fn verify_source(&self) -> anyhow::Result<()> {
        (**self).verify_source()
    }
}

/// Caps an (often infinite) stream at `n` arrivals. The DES drivers wrap
/// every source in this so a tenant delivers exactly `requests` arrivals
/// no matter what the underlying process would produce.
pub struct Bounded<S: ArrivalStream> {
    inner: S,
    left: usize,
}

impl<S: ArrivalStream> Bounded<S> {
    pub fn new(inner: S, n: usize) -> Bounded<S> {
        Bounded { inner, left: n }
    }
}

impl<S: ArrivalStream> ArrivalStream for Bounded<S> {
    fn next_arrival(&mut self) -> Option<Arrival> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        self.inner.next_arrival()
    }

    fn rate_hint(&self) -> Option<f64> {
        self.inner.rate_hint()
    }

    fn duration_hint_s(&self) -> Option<f64> {
        self.inner.duration_hint_s()
    }

    fn verify_source(&self) -> anyhow::Result<()> {
        self.inner.verify_source()
    }
}

/// Cursor over a materialized [`ReplayTrace`]: yields the same arrivals,
/// in the same order, with the same length draws from `rng`, as
/// [`ReplayTrace::arrivals`] would materialize.
pub struct ReplayCursor {
    at_s: Vec<f64>,
    pos: usize,
    model: ModelId,
    rng: Rng,
}

impl ReplayCursor {
    pub fn new(trace: &ReplayTrace, model: ModelId, rng: Rng) -> ReplayCursor {
        ReplayCursor { at_s: trace.timestamps_s().to_vec(), pos: 0, model, rng }
    }
}

impl ArrivalStream for ReplayCursor {
    fn next_arrival(&mut self) -> Option<Arrival> {
        let &t = self.at_s.get(self.pos)?;
        self.pos += 1;
        Some(Arrival { at: secs(t), len_s: draw_len(self.model, &mut self.rng) })
    }

    fn rate_hint(&self) -> Option<f64> {
        let dur = *self.at_s.last()?;
        Some(self.at_s.len() as f64 / dur.max(1e-9))
    }

    fn duration_hint_s(&self) -> Option<f64> {
        self.at_s.last().copied()
    }
}

/// Per-request input length for `model` (same sampler the eager paths
/// use: audio from the LibriSpeech mixture, vision fixed at 0 s).
fn draw_len(model: ModelId, rng: &mut Rng) -> f64 {
    match model.kind() {
        ModelKind::Vision => 0.0,
        ModelKind::Audio => sample_librispeech_len(rng),
    }
}

// ---------------------------------------------------------------------
// Timestamp streams: bare monotone seconds sources.
// ---------------------------------------------------------------------

/// A monotone stream of arrival timestamps (seconds from trace start).
/// The building block under [`WithLengths`]; file readers and the
/// synthetic generator speak this so rescaling adapters compose.
pub trait TimestampStream {
    fn next_ts(&mut self) -> Option<f64>;
}

impl TimestampStream for Box<dyn TimestampStream> {
    fn next_ts(&mut self) -> Option<f64> {
        (**self).next_ts()
    }
}

/// Streaming equivalent of [`ReplayTrace::synth_azure`]: the identical
/// thinned-Poisson state machine (diurnal envelope × MMPP burst overlay),
/// yielding timestamps one at a time instead of materializing. For the
/// same `(seed, duration_s, base_qps)` the sequence is bit-identical to
/// the materialized trace — `synth_azure` is now implemented as a
/// collect of this stream.
#[derive(Debug, Clone)]
pub struct SynthAzure {
    rng: Rng,
    duration_s: f64,
    period_s: f64,
    base: f64,
    lambda_max: f64,
    quiet_s: f64,
    burst_s: f64,
    t: f64,
    in_burst: bool,
    next_switch: f64,
}

impl SynthAzure {
    /// Diurnal swing of the envelope (±60%).
    const AMPLITUDE: f64 = 0.6;
    /// Rate multiplier while a burst is active.
    const BURST_X: f64 = 3.0;

    pub fn new(seed: u64, duration_s: f64, base_qps: f64) -> SynthAzure {
        assert!(duration_s > 0.0 && base_qps > 0.0);
        let mut rng = Rng::new(seed ^ 0xA27E_57AC_E5);
        let period_s = duration_s / 2.0;
        // Burst dwell ≪ quiet dwell: spikes, not regimes. The long-run
        // burst fraction is dwell_burst/(dwell_burst+dwell_quiet) = 1/11,
        // so the stationary rate multiplier is ~1.18; fold it out of
        // `base` to keep the realized mean near `base_qps`.
        let quiet_s = duration_s / 12.0;
        let burst_s = duration_s / 120.0;
        let burst_frac = burst_s / (burst_s + quiet_s);
        let base = base_qps / (1.0 + (Self::BURST_X - 1.0) * burst_frac);
        let lambda_max = base * (1.0 + Self::AMPLITUDE) * Self::BURST_X;
        let next_switch = rng.exp(1.0 / quiet_s);
        SynthAzure {
            rng,
            duration_s,
            period_s,
            base,
            lambda_max,
            quiet_s,
            burst_s,
            t: 0.0,
            in_burst: false,
            next_switch,
        }
    }
}

impl TimestampStream for SynthAzure {
    fn next_ts(&mut self) -> Option<f64> {
        loop {
            self.t += self.rng.exp(self.lambda_max);
            if self.t > self.duration_s {
                return None;
            }
            while self.t >= self.next_switch {
                self.in_burst = !self.in_burst;
                let dwell = if self.in_burst { self.burst_s } else { self.quiet_s };
                self.next_switch += self.rng.exp(1.0 / dwell);
            }
            let angle = 2.0 * std::f64::consts::PI * self.t / self.period_s;
            let mut lambda = self.base * (1.0 + Self::AMPLITUDE * angle.sin());
            if self.in_burst {
                lambda *= Self::BURST_X;
            }
            if self.rng.f64() <= lambda / self.lambda_max {
                return Some(self.t);
            }
        }
    }
}

/// Lifts a [`TimestampStream`] to an [`ArrivalStream`] by drawing one
/// input length per arrival from `rng` — the draw order matches
/// [`ReplayTrace::arrivals`] on the materialized equivalent.
pub struct WithLengths<S: TimestampStream> {
    inner: S,
    model: ModelId,
    rng: Rng,
    rate_hint: Option<f64>,
    duration_hint_s: Option<f64>,
}

impl<S: TimestampStream> WithLengths<S> {
    pub fn new(inner: S, model: ModelId, rng: Rng) -> WithLengths<S> {
        WithLengths { inner, model, rng, rate_hint: None, duration_hint_s: None }
    }

    /// Attach rate/duration hints (usually from a [`StreamSpec`] probe).
    pub fn with_hints(mut self, rate_qps: Option<f64>, duration_s: Option<f64>) -> Self {
        self.rate_hint = rate_qps;
        self.duration_hint_s = duration_s;
        self
    }
}

impl<S: TimestampStream> ArrivalStream for WithLengths<S> {
    fn next_arrival(&mut self) -> Option<Arrival> {
        let t = self.inner.next_ts()?;
        Some(Arrival { at: secs(t), len_s: draw_len(self.model, &mut self.rng) })
    }

    fn rate_hint(&self) -> Option<f64> {
        self.rate_hint
    }

    fn duration_hint_s(&self) -> Option<f64> {
        self.duration_hint_s
    }
}

// ---------------------------------------------------------------------
// Chunked trace-file readers.
// ---------------------------------------------------------------------

/// Streaming CSV trace reader: one record per line, first field is the
/// timestamp in seconds; blank lines, `#` comments, and one non-numeric
/// header line are skipped — the same grammar as
/// [`ReplayTrace::from_csv`], but holding only the current line in
/// memory. [`scan_trace_file`] runs this same reader as a validation
/// pass, so the streaming replay pass ([`TimestampStream::next_ts`])
/// treats any residual error (a file mutated between passes) as
/// end-of-stream.
pub struct CsvTraceReader {
    rd: BufReader<File>,
    line: String,
    lineno: usize,
    prev: Option<f64>,
}

impl CsvTraceReader {
    pub fn open(path: &str) -> anyhow::Result<CsvTraceReader> {
        let f =
            File::open(path).map_err(|e| anyhow::anyhow!("cannot read trace '{path}': {e}"))?;
        Ok(CsvTraceReader { rd: BufReader::new(f), line: String::new(), lineno: 0, prev: None })
    }

    /// The next timestamp, or a parse/order error naming the line.
    pub fn try_next_ts(&mut self) -> anyhow::Result<Option<f64>> {
        loop {
            self.line.clear();
            if self.rd.read_line(&mut self.line)? == 0 {
                return Ok(None);
            }
            self.lineno += 1;
            let line = self.line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let field = line.split(',').next().unwrap_or("").trim();
            match field.parse::<f64>() {
                Ok(t) => {
                    anyhow::ensure!(
                        t.is_finite() && t >= 0.0,
                        "trace CSV line {}: bad timestamp {t}",
                        self.lineno
                    );
                    if let Some(prev) = self.prev {
                        anyhow::ensure!(
                            t >= prev,
                            "trace CSV line {}: timestamp {t} runs backwards (previous {prev})",
                            self.lineno
                        );
                    }
                    self.prev = Some(t);
                    return Ok(Some(t));
                }
                // A header is only acceptable before any data row.
                Err(_) if self.prev.is_none() => continue,
                Err(_) => {
                    anyhow::bail!("trace CSV line {}: bad timestamp '{field}'", self.lineno)
                }
            }
        }
    }
}

impl TimestampStream for CsvTraceReader {
    fn next_ts(&mut self) -> Option<f64> {
        self.try_next_ts().ok().flatten()
    }
}

/// Streaming JSON trace reader: scans to the first `[` and yields the
/// comma-separated numbers up to the matching first `]` — the same
/// grammar as [`ReplayTrace::from_json`], but reading the file in
/// buffered chunks instead of one giant string.
pub struct JsonTraceReader {
    rd: BufReader<File>,
    in_array: bool,
    done: bool,
    elem: usize,
    prev: Option<f64>,
}

impl JsonTraceReader {
    pub fn open(path: &str) -> anyhow::Result<JsonTraceReader> {
        let f =
            File::open(path).map_err(|e| anyhow::anyhow!("cannot read trace '{path}': {e}"))?;
        Ok(JsonTraceReader {
            rd: BufReader::new(f),
            in_array: false,
            done: false,
            elem: 0,
            prev: None,
        })
    }

    fn next_byte(&mut self) -> anyhow::Result<Option<u8>> {
        let buf = self.rd.fill_buf()?;
        if buf.is_empty() {
            return Ok(None);
        }
        let b = buf[0];
        self.rd.consume(1);
        Ok(Some(b))
    }

    /// The next timestamp, or a parse/order error naming the element.
    pub fn try_next_ts(&mut self) -> anyhow::Result<Option<f64>> {
        if self.done {
            return Ok(None);
        }
        while !self.in_array {
            match self.next_byte()? {
                Some(b'[') => self.in_array = true,
                Some(_) => continue,
                None => anyhow::bail!("no JSON array in trace"),
            }
        }
        let mut tok = String::new();
        loop {
            let (end_of_array, end_of_elem) = match self.next_byte()? {
                Some(b']') => (true, true),
                Some(b',') => (false, true),
                Some(b) => {
                    tok.push(b as char);
                    (false, false)
                }
                None => anyhow::bail!("unterminated JSON array in trace"),
            };
            if !end_of_elem {
                continue;
            }
            self.done = end_of_array;
            let i = self.elem;
            self.elem += 1;
            let trimmed = tok.trim();
            if trimmed.is_empty() {
                if self.done {
                    return Ok(None);
                }
                tok.clear();
                continue;
            }
            let t = trimmed.parse::<f64>().map_err(|_| {
                anyhow::anyhow!("JSON trace element {i}: bad timestamp '{trimmed}'")
            })?;
            anyhow::ensure!(t.is_finite() && t >= 0.0, "JSON trace element {i}: bad timestamp {t}");
            if let Some(prev) = self.prev {
                anyhow::ensure!(
                    t >= prev,
                    "JSON trace element {i}: timestamp {t} runs backwards (previous {prev})"
                );
            }
            self.prev = Some(t);
            return Ok(Some(t));
        }
    }
}

impl TimestampStream for JsonTraceReader {
    fn next_ts(&mut self) -> Option<f64> {
        self.try_next_ts().ok().flatten()
    }
}

/// Extension-dispatched chunked trace-file reader (`.json` → JSON,
/// anything else → CSV — the same rule as [`ReplayTrace::load`]).
pub enum TraceFileReader {
    Csv(CsvTraceReader),
    Json(JsonTraceReader),
}

impl TraceFileReader {
    pub fn open(path: &str) -> anyhow::Result<TraceFileReader> {
        if path.ends_with(".json") {
            Ok(TraceFileReader::Json(JsonTraceReader::open(path)?))
        } else {
            Ok(TraceFileReader::Csv(CsvTraceReader::open(path)?))
        }
    }

    pub fn try_next_ts(&mut self) -> anyhow::Result<Option<f64>> {
        match self {
            TraceFileReader::Csv(r) => r.try_next_ts(),
            TraceFileReader::Json(r) => r.try_next_ts(),
        }
    }
}

impl TimestampStream for TraceFileReader {
    fn next_ts(&mut self) -> Option<f64> {
        self.try_next_ts().ok().flatten()
    }
}

/// Shape summary of a timestamp source from a full validation pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceScan {
    /// Number of timestamps.
    pub len: usize,
    /// First timestamp, seconds.
    pub first_s: f64,
    /// Last timestamp, seconds (the trace span).
    pub last_s: f64,
}

/// Validate a trace file end-to-end in O(1) memory and report its shape.
/// This is the pass-1 of the two-pass streaming protocol: every
/// malformed row is rejected here with line/element context, so the
/// replay pass can treat errors as end-of-stream.
pub fn scan_trace_file(path: &str) -> anyhow::Result<TraceScan> {
    let mut rd = TraceFileReader::open(path)?;
    let scan = scan_ts(|| rd.try_next_ts()).map_err(|e| anyhow::anyhow!("trace '{path}': {e}"))?;
    scan.ok_or_else(|| {
        let what = if path.ends_with(".json") {
            "JSON trace array is empty"
        } else {
            "trace CSV has no data rows"
        };
        anyhow::anyhow!("trace '{path}': {what}")
    })
}

/// Drain a fallible timestamp source, returning its shape (or `None` if
/// it yields nothing).
fn scan_ts(
    mut next: impl FnMut() -> anyhow::Result<Option<f64>>,
) -> anyhow::Result<Option<TraceScan>> {
    let mut scan: Option<TraceScan> = None;
    while let Some(t) = next()? {
        match &mut scan {
            None => scan = Some(TraceScan { len: 1, first_s: t, last_s: t }),
            Some(s) => {
                s.len += 1;
                s.last_s = t;
            }
        }
    }
    Ok(scan)
}

// ---------------------------------------------------------------------
// Rescaling adapters (streaming equivalents of `ReplayTrace::rescaled`).
// ---------------------------------------------------------------------

/// Divides every timestamp by `factor` — the streaming form of
/// [`crate::workload::Rescale::Factor`] (identical float op, so scaled
/// streams stay bit-identical to scaled materialized traces).
pub struct ScaleTs {
    inner: Box<dyn TimestampStream>,
    factor: f64,
}

impl ScaleTs {
    pub fn new(inner: Box<dyn TimestampStream>, factor: f64) -> ScaleTs {
        assert!(factor > 0.0, "rate scale must be positive");
        ScaleTs { inner, factor }
    }
}

impl TimestampStream for ScaleTs {
    fn next_ts(&mut self) -> Option<f64> {
        self.inner.next_ts().map(|t| t / self.factor)
    }
}

/// I.i.d. thinning with keep-probability `keep` — the streaming form of
/// [`crate::workload::Rescale::Thin`]: the same `Rng` stream and the
/// same `f64() < keep` test per candidate, so the surviving timestamps
/// match `thinned_to_qps` exactly, including the degenerate all-dropped
/// case (which yields the first timestamp once, at end-of-source).
pub struct ThinTs {
    inner: Box<dyn TimestampStream>,
    keep: f64,
    rng: Rng,
    first: Option<f64>,
    kept: usize,
}

impl ThinTs {
    /// `seed` matches the `thinned_to_qps` seed parameter (the reader
    /// mixes in the same constant internally).
    pub fn new(inner: Box<dyn TimestampStream>, keep: f64, seed: u64) -> ThinTs {
        ThinTs { inner, keep, rng: Rng::new(seed ^ 0x7417_11ED), first: None, kept: 0 }
    }
}

impl TimestampStream for ThinTs {
    fn next_ts(&mut self) -> Option<f64> {
        loop {
            match self.inner.next_ts() {
                Some(t) => {
                    if self.first.is_none() {
                        self.first = Some(t);
                    }
                    if self.rng.f64() < self.keep {
                        self.kept += 1;
                        return Some(t);
                    }
                }
                None => {
                    if self.kept == 0 {
                        // Degenerate target (keep-probability ~0): one
                        // arrival is the smallest non-empty replay.
                        if let Some(f) = self.first.take() {
                            self.kept = 1;
                            return Some(f);
                        }
                    }
                    return None;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// StreamSpec: a cloneable description a tenant can carry.
// ---------------------------------------------------------------------

/// Where a [`StreamSpec`]'s raw timestamps come from.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamSource {
    /// The deterministic Azure-shaped synthetic generator
    /// ([`SynthAzure`]) — multi-million-row traces at zero memory.
    Azure { seed: u64, duration_s: f64, base_qps: f64 },
    /// A CSV/JSON trace file, read in bounded-memory chunks.
    File { path: String },
}

/// A cloneable, openable description of an arrival stream: raw source
/// plus the rescale knobs the CLI trace path applies (fit the span onto
/// the simulated horizon, then thin to a per-tenant rate). Stored on
/// `ClusterTenant` so a config stays `Clone + Send + Sync`; each DES
/// run opens its own live stream.
///
/// Opening is a two-pass protocol: [`StreamSpec::probe`] validates the
/// source end-to-end and computes the final shape (request count, mean
/// rate, span) in O(1) memory; [`StreamSpec::open`] replays it lazily.
/// Both passes are deterministic, so `probe().requests` is exactly the
/// number of arrivals the opened stream yields.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSpec {
    pub source: StreamSource,
    /// Stretch/compress the timeline onto this span (seconds) —
    /// equivalent to [`crate::workload::Rescale::ToDuration`].
    pub fit_duration_s: Option<f64>,
    /// Thin to this mean rate (queries/s) after fitting — equivalent to
    /// [`crate::workload::Rescale::Thin`]. Ignored at or above the
    /// source's mean rate (replay cannot invent arrivals).
    pub thin_qps: Option<f64>,
    /// Seed for the thinning filter.
    pub thin_seed: u64,
}

/// Final shape of a [`StreamSpec`] after rescaling, from a probe pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamProbe {
    /// Exact number of arrivals the opened stream yields.
    pub requests: usize,
    /// Mean offered rate of the final stream, queries/s.
    pub mean_qps: f64,
    /// Span of the final stream, seconds.
    pub duration_s: f64,
}

impl StreamSpec {
    /// A plain source with no rescaling.
    pub fn new(source: StreamSource) -> StreamSpec {
        StreamSpec { source, fit_duration_s: None, thin_qps: None, thin_seed: 0 }
    }

    /// Synthetic Azure-shaped source (see [`SynthAzure`]).
    pub fn azure(seed: u64, duration_s: f64, base_qps: f64) -> StreamSpec {
        StreamSpec::new(StreamSource::Azure { seed, duration_s, base_qps })
    }

    /// Chunked CSV/JSON file source (see [`TraceFileReader`]).
    pub fn file(path: impl Into<String>) -> StreamSpec {
        StreamSpec::new(StreamSource::File { path: path.into() })
    }

    /// Fit the timeline onto `duration_s` (builder-style).
    pub fn fit_duration(mut self, duration_s: f64) -> StreamSpec {
        assert!(duration_s > 0.0, "duration must be positive");
        self.fit_duration_s = Some(duration_s);
        self
    }

    /// Thin to a ~`qps` mean with a seeded filter (builder-style).
    pub fn thin_to_qps(mut self, qps: f64, seed: u64) -> StreamSpec {
        assert!(qps > 0.0, "target rate must be positive");
        self.thin_qps = Some(qps);
        self.thin_seed = seed;
        self
    }

    /// One validating pass over the raw source.
    fn scan_source(&self) -> anyhow::Result<TraceScan> {
        match &self.source {
            StreamSource::Azure { seed, duration_s, base_qps } => {
                let mut gen = SynthAzure::new(*seed, *duration_s, *base_qps);
                scan_ts(|| Ok(gen.next_ts()))?
                    .ok_or_else(|| anyhow::anyhow!("synthetic trace is empty"))
            }
            StreamSource::File { path } => scan_trace_file(path),
        }
    }

    /// Open the raw source for a replay pass (already validated).
    fn open_source(&self) -> anyhow::Result<Box<dyn TimestampStream>> {
        Ok(match &self.source {
            StreamSource::Azure { seed, duration_s, base_qps } => {
                Box::new(SynthAzure::new(*seed, *duration_s, *base_qps))
            }
            StreamSource::File { path } => Box::new(TraceFileReader::open(path)?),
        })
    }

    /// Timeline-compression factor and scaled span from a raw scan —
    /// float-for-float the computation `scaled_to_duration` does, so
    /// scaled timestamps match the materialized path bit-for-bit.
    fn fit(&self, raw: &TraceScan) -> (Option<f64>, f64) {
        match self.fit_duration_s {
            Some(d) => {
                let factor = raw.last_s.max(1e-9) / d;
                (Some(factor), raw.last_s / factor)
            }
            None => (None, raw.last_s),
        }
    }

    /// Keep-probability for the thinning stage (`None` = no thinning,
    /// including targets at/above the source mean).
    fn keep_prob(&self, len: usize, scaled_dur: f64) -> Option<f64> {
        let qps = self.thin_qps?;
        let mean = len as f64 / scaled_dur.max(1e-9);
        let keep = qps / mean;
        (keep < 1.0).then_some(keep)
    }

    /// Validate the source and compute the final stream shape (request
    /// count, mean rate, span) without materializing anything. Costs one
    /// source pass, or two when thinning below the source rate.
    pub fn probe(&self) -> anyhow::Result<StreamProbe> {
        let raw = self.scan_source()?;
        let (factor, scaled_dur) = self.fit(&raw);
        let scale = |t: f64| factor.map_or(t, |f| t / f);
        let Some(keep) = self.keep_prob(raw.len, scaled_dur) else {
            return Ok(StreamProbe {
                requests: raw.len,
                mean_qps: raw.len as f64 / scaled_dur.max(1e-9),
                duration_s: scaled_dur,
            });
        };
        // Second pass: replay the thinning filter to count survivors.
        let mut src = self.open_source()?;
        let mut rng = Rng::new(self.thin_seed ^ 0x7417_11ED);
        let mut kept = 0usize;
        let mut last_kept = scale(raw.first_s);
        while let Some(t) = src.next_ts() {
            if rng.f64() < keep {
                kept += 1;
                last_kept = scale(t);
            }
        }
        let requests = kept.max(1); // all-dropped => first timestamp once
        Ok(StreamProbe {
            requests,
            mean_qps: requests as f64 / last_kept.max(1e-9),
            duration_s: last_kept,
        })
    }

    /// Open the stream for a run: raw source → optional timeline fit →
    /// optional thinning → per-arrival length draws from `gen_rng`.
    /// Arrival-for-arrival identical to materializing the source as a
    /// [`ReplayTrace`], applying the equivalent `rescaled` calls, and
    /// calling `arrivals(model, gen_rng)`. File-backed streams come back
    /// wrapped in a [`SourceGuard`] so the driver can confirm at the end
    /// of replay that the file never changed underneath the run.
    pub fn open(&self, model: ModelId, gen_rng: Rng) -> anyhow::Result<Box<dyn ArrivalStream>> {
        let raw = self.scan_source()?;
        let (factor, scaled_dur) = self.fit(&raw);
        let mut ts: Box<dyn TimestampStream> = self.open_source()?;
        if let Some(f) = factor {
            ts = Box::new(ScaleTs::new(ts, f));
        }
        let mut len = raw.len;
        if let Some(keep) = self.keep_prob(raw.len, scaled_dur) {
            ts = Box::new(ThinTs::new(ts, keep, self.thin_seed));
            len = 0; // final length only known from probe(); hint below
        }
        let probe_hint = if len == 0 { self.probe().ok() } else { None };
        let (rate, dur) = match probe_hint {
            Some(p) => (Some(p.mean_qps), Some(p.duration_s)),
            None => (Some(len as f64 / scaled_dur.max(1e-9)), Some(scaled_dur)),
        };
        let stream: Box<dyn ArrivalStream> =
            Box::new(WithLengths::new(ts, model, gen_rng).with_hints(rate, dur));
        Ok(match &self.source {
            StreamSource::File { path } => {
                Box::new(SourceGuard { inner: stream, path: path.clone(), raw })
            }
            _ => stream,
        })
    }
}

/// Pairs a file-backed stream with the shape its sizing scan saw, so the
/// two-pass protocol's blind spot is closed: the replay pass treats a
/// read error as end-of-stream (by design — pass 1 validated the file),
/// which means a trace rewritten on disk mid-run would otherwise replay
/// a silent hybrid of old and new bytes. [`ArrivalStream::verify_source`]
/// re-scans the file after the run and demands the identical shape
/// (row count and first/last timestamps).
pub struct SourceGuard {
    inner: Box<dyn ArrivalStream>,
    path: String,
    raw: TraceScan,
}

impl ArrivalStream for SourceGuard {
    fn next_arrival(&mut self) -> Option<Arrival> {
        self.inner.next_arrival()
    }

    fn rate_hint(&self) -> Option<f64> {
        self.inner.rate_hint()
    }

    fn duration_hint_s(&self) -> Option<f64> {
        self.inner.duration_hint_s()
    }

    fn verify_source(&self) -> anyhow::Result<()> {
        let now = scan_trace_file(&self.path)
            .map_err(|e| e.context("trace became unreadable during replay"))?;
        anyhow::ensure!(
            now == self.raw,
            "trace '{}' changed on disk during replay: opened with {} rows \
             spanning [{}, {}] s, file now has {} rows spanning [{}, {}] s",
            self.path,
            self.raw.len,
            self.raw.first_s,
            self.raw.last_s,
            now.len,
            now.first_s,
            now.last_s
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Rescale;

    fn collect_ts(mut s: impl TimestampStream) -> Vec<f64> {
        std::iter::from_fn(|| s.next_ts()).collect()
    }

    fn collect_arrivals(mut s: impl ArrivalStream) -> Vec<Arrival> {
        std::iter::from_fn(|| s.next_arrival()).collect()
    }

    fn tmp_path(name: &str) -> String {
        let dir = std::env::temp_dir().join("preba_stream_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_str().unwrap().to_string()
    }

    #[test]
    fn query_gen_stream_matches_take() {
        let eager = QueryGen::new(ModelId::CitriNet, 80.0, Rng::new(11)).take(500);
        let gen = QueryGen::new(ModelId::CitriNet, 80.0, Rng::new(11));
        assert_eq!(gen.rate_hint(), Some(80.0));
        let lazy = collect_arrivals(Bounded::new(gen, 500));
        assert_eq!(lazy.len(), 500);
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.len_s.to_bits(), b.len_s.to_bits());
        }
    }

    #[test]
    fn replay_cursor_matches_materialized_arrivals() {
        let t = ReplayTrace::synth_azure(3, 20.0, 50.0);
        let eager = t.arrivals(ModelId::CitriNet, &mut Rng::new(9));
        let lazy = collect_arrivals(t.cursor(ModelId::CitriNet, Rng::new(9)));
        assert_eq!(eager.len(), lazy.len());
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.len_s.to_bits(), b.len_s.to_bits());
        }
    }

    #[test]
    fn synth_azure_stream_matches_materialized_trace() {
        let eager = ReplayTrace::synth_azure(7, 40.0, 300.0);
        let lazy = collect_ts(SynthAzure::new(7, 40.0, 300.0));
        assert_eq!(eager.timestamps_s(), &lazy[..]);
    }

    #[test]
    fn bounded_caps_infinite_sources() {
        let gen = TraceGen::new(
            ModelId::MobileNet,
            crate::workload::RateProfile::Constant { qps: 40.0 },
            Rng::new(4),
        );
        let got = collect_arrivals(Bounded::new(gen, 37));
        assert_eq!(got.len(), 37);
    }

    #[test]
    fn csv_reader_matches_from_csv() {
        let text = "ts,extra\n# comment\n0.25,a\n0.5,b\n\n1.5,c\n";
        let path = tmp_path("match.csv");
        std::fs::write(&path, text).unwrap();
        let eager = ReplayTrace::from_csv(text).unwrap();
        let lazy = collect_ts(CsvTraceReader::open(&path).unwrap());
        assert_eq!(eager.timestamps_s(), &lazy[..]);
        assert_eq!(
            scan_trace_file(&path).unwrap(),
            TraceScan { len: 3, first_s: 0.25, last_s: 1.5 }
        );
    }

    #[test]
    fn json_reader_matches_from_json() {
        let text = "{\"arrivals_s\": [0.25, 0.5, 1.5]}";
        let path = tmp_path("match.json");
        std::fs::write(&path, text).unwrap();
        let eager = ReplayTrace::from_json(text).unwrap();
        let lazy = collect_ts(JsonTraceReader::open(&path).unwrap());
        assert_eq!(eager.timestamps_s(), &lazy[..]);
    }

    #[test]
    fn scan_rejects_corrupt_files_with_context() {
        let path = tmp_path("corrupt.csv");
        std::fs::write(&path, "h1\n1.0\nnot-a-number\n").unwrap();
        let err = scan_trace_file(&path).unwrap_err().to_string();
        assert!(err.contains("line 3") && err.contains("not-a-number"), "{err}");
        let path = tmp_path("backwards.json");
        std::fs::write(&path, "[1.0, 0.5]").unwrap();
        let err = scan_trace_file(&path).unwrap_err().to_string();
        assert!(err.contains("backwards"), "{err}");
        let path = tmp_path("empty.csv");
        std::fs::write(&path, "# only comments\n").unwrap();
        assert!(scan_trace_file(&path).is_err());
    }

    #[test]
    fn spec_rescaling_matches_materialized_rescale() {
        // Azure source, fit onto a 10 s horizon, thinned to a low rate:
        // the full CLI trace pipeline, streamed vs materialized.
        let spec = StreamSpec::azure(21, 30.0, 200.0).fit_duration(10.0).thin_to_qps(40.0, 77);
        let raw = ReplayTrace::synth_azure(21, 30.0, 200.0);
        let eager = raw
            .rescaled(Rescale::ToDuration(10.0))
            .rescaled(Rescale::Thin { qps: 40.0, seed: 77 })
            .arrivals(ModelId::CitriNet, &mut Rng::new(5));
        let probe = spec.probe().unwrap();
        assert_eq!(probe.requests, eager.len());
        let lazy = collect_arrivals(spec.open(ModelId::CitriNet, Rng::new(5)).unwrap());
        assert_eq!(eager.len(), lazy.len());
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.len_s.to_bits(), b.len_s.to_bits());
        }
    }

    #[test]
    fn spec_degenerate_thin_yields_one_arrival() {
        let spec = StreamSpec::azure(5, 10.0, 30.0).thin_to_qps(1e-9, 3);
        let raw = ReplayTrace::synth_azure(5, 10.0, 30.0);
        let eager = raw.rescaled(Rescale::Thin { qps: 1e-9, seed: 3 });
        assert_eq!(eager.len(), 1);
        assert_eq!(spec.probe().unwrap().requests, 1);
        let lazy = collect_arrivals(spec.open(ModelId::MobileNet, Rng::new(1)).unwrap());
        assert_eq!(lazy.len(), 1);
        assert_eq!(lazy[0].at, secs(eager.timestamps_s()[0]));
    }

    #[test]
    fn guard_detects_trace_mutated_during_replay() {
        let path = tmp_path("mutate.csv");
        std::fs::write(&path, "0.25\n0.5\n1.5\n").unwrap();
        let spec = StreamSpec::file(&path);
        let mut s = spec.open(ModelId::MobileNet, Rng::new(3)).unwrap();
        assert!(s.verify_source().is_ok());
        s.next_arrival().unwrap();
        // The file grows mid-run (e.g. a collector still appending).
        std::fs::write(&path, "0.25\n0.5\n1.5\n2.0\n").unwrap();
        let err = s.verify_source().unwrap_err().to_string();
        assert!(err.contains("changed on disk during replay"), "{err}");
        assert!(err.contains("3 rows") && err.contains("4 rows"), "{err}");
        // Restoring the original content clears the alarm.
        std::fs::write(&path, "0.25\n0.5\n1.5\n").unwrap();
        assert!(s.verify_source().is_ok());
        // Synthetic sources are trivially stable.
        let azure = StreamSpec::azure(1, 5.0, 20.0);
        let s = azure.open(ModelId::MobileNet, Rng::new(3)).unwrap();
        assert!(s.verify_source().is_ok());
    }

    #[test]
    fn spec_probe_counts_match_open_counts() {
        for (fit, thin) in
            [(None, None), (Some(7.0), None), (None, Some(25.0)), (Some(5.0), Some(10.0))]
        {
            let mut spec = StreamSpec::azure(13, 20.0, 80.0);
            if let Some(d) = fit {
                spec = spec.fit_duration(d);
            }
            if let Some(q) = thin {
                spec = spec.thin_to_qps(q, 42);
            }
            let probe = spec.probe().unwrap();
            let got = collect_arrivals(spec.open(ModelId::MobileNet, Rng::new(2)).unwrap());
            assert_eq!(probe.requests, got.len(), "fit={fit:?} thin={thin:?}");
        }
    }
}
