//! Non-stationary traffic: real inference servers see diurnal cycles and
//! bursts, not a constant-rate Poisson stream (paper §3.2: "input traffic
//! patterns are constantly changing with varying traffic intensities").
//!
//! Three generators on top of the Poisson thinning method:
//! * [`RateProfile::Constant`] — the MLPerf-server baseline.
//! * [`RateProfile::Diurnal`] — sinusoidal day/night swing.
//! * [`RateProfile::Bursty`] — Markov-modulated Poisson (quiet/burst
//!   states), the adversarial case for a batching system: bursts fill
//!   batches instantly while quiet periods leave requests waiting on
//!   `Time_queue`.
//!
//! Plus **trace replay** ([`ReplayTrace`]): recorded arrival timestamps
//! (CSV / JSON) driven through the cluster DES verbatim, with a
//! rate-scaling knob and a bundled Azure-Functions-style synthetic
//! generator ([`ReplayTrace::synth_azure`]) so fleet experiments can run
//! against realistic recorded traffic without shipping a dataset.

use crate::clock::{secs, Nanos};
use crate::models::{ModelId, ModelKind};
use crate::util::Rng;

use super::stream::{ReplayCursor, SynthAzure, TimestampStream};
use super::{sample_librispeech_len, Arrival};

/// Time-varying offered-rate profile, queries/s at time `t`.
#[derive(Debug, Clone)]
pub enum RateProfile {
    /// Fixed rate.
    Constant { qps: f64 },
    /// `base * (1 + amplitude * sin(2π (t/period + phase)))`. `phase_frac`
    /// shifts the cycle (0.5 = anti-phase — two tenants peaking in
    /// opposite halves of the day, the multi-tenant reconfig scenario).
    Diurnal { base_qps: f64, amplitude: f64, period_s: f64, phase_frac: f64 },
    /// Two-state MMPP: quiet rate / burst rate with exponential dwell
    /// times.
    Bursty {
        quiet_qps: f64,
        burst_qps: f64,
        mean_quiet_s: f64,
        mean_burst_s: f64,
    },
}

impl RateProfile {
    /// Instantaneous rate at `t_s` (burst state handled by the generator).
    pub fn rate_at(&self, t_s: f64, in_burst: bool) -> f64 {
        match self {
            RateProfile::Constant { qps } => *qps,
            RateProfile::Diurnal { base_qps, amplitude, period_s, phase_frac } => {
                let angle = 2.0 * std::f64::consts::PI * (t_s / period_s + phase_frac);
                base_qps * (1.0 + amplitude * angle.sin())
            }
            RateProfile::Bursty { quiet_qps, burst_qps, .. } => {
                if in_burst {
                    *burst_qps
                } else {
                    *quiet_qps
                }
            }
        }
        .max(1e-6)
    }

    /// Upper bound of the rate (for Poisson thinning).
    pub fn max_rate(&self) -> f64 {
        match self {
            RateProfile::Constant { qps } => *qps,
            RateProfile::Diurnal { base_qps, amplitude, .. } => base_qps * (1.0 + amplitude.abs()),
            RateProfile::Bursty { quiet_qps, burst_qps, .. } => quiet_qps.max(*burst_qps),
        }
    }

    /// Named profile shapes around a base rate (CLI `--profile` and the
    /// reconfiguration experiments' defaults).
    pub fn named(kind: &str, base_qps: f64) -> Option<RateProfile> {
        match kind {
            "constant" => Some(RateProfile::Constant { qps: base_qps }),
            "diurnal" => Some(RateProfile::Diurnal {
                base_qps,
                amplitude: 0.7,
                period_s: 30.0,
                phase_frac: 0.0,
            }),
            "bursty" => Some(RateProfile::Bursty {
                quiet_qps: 0.25 * base_qps,
                burst_qps: 2.5 * base_qps,
                mean_quiet_s: 4.0,
                mean_burst_s: 1.5,
            }),
            _ => None,
        }
    }

    /// Long-run mean rate.
    pub fn mean_rate(&self) -> f64 {
        match self {
            RateProfile::Constant { qps } => *qps,
            RateProfile::Diurnal { base_qps, .. } => *base_qps,
            RateProfile::Bursty { quiet_qps, burst_qps, mean_quiet_s, mean_burst_s } => {
                (quiet_qps * mean_quiet_s + burst_qps * mean_burst_s)
                    / (mean_quiet_s + mean_burst_s)
            }
        }
    }
}

/// Non-stationary arrival generator (thinning / state-switching).
#[derive(Debug)]
pub struct TraceGen {
    model: ModelId,
    profile: RateProfile,
    rng: Rng,
    t_s: f64,
    in_burst: bool,
    /// Next burst/quiet state switch (bursty profile only).
    next_switch_s: f64,
}

impl TraceGen {
    pub fn new(model: ModelId, profile: RateProfile, mut rng: Rng) -> TraceGen {
        let next_switch_s = match &profile {
            RateProfile::Bursty { mean_quiet_s, .. } => rng.exp(1.0 / mean_quiet_s),
            _ => f64::INFINITY,
        };
        TraceGen { model, profile, rng, t_s: 0.0, in_burst: false, next_switch_s }
    }

    fn advance_state(&mut self) {
        if let RateProfile::Bursty { mean_quiet_s, mean_burst_s, .. } = self.profile {
            while self.t_s >= self.next_switch_s {
                self.in_burst = !self.in_burst;
                let dwell =
                    if self.in_burst { mean_burst_s } else { mean_quiet_s };
                self.next_switch_s += self.rng.exp(1.0 / dwell);
            }
        }
    }

    /// Next arrival via Poisson thinning against `max_rate`.
    pub fn next(&mut self) -> Arrival {
        let lambda_max = self.profile.max_rate();
        loop {
            self.t_s += self.rng.exp(lambda_max);
            self.advance_state();
            let lambda = self.profile.rate_at(self.t_s, self.in_burst);
            if self.rng.f64() <= lambda / lambda_max {
                let len_s = match self.model.kind() {
                    ModelKind::Vision => 0.0,
                    ModelKind::Audio => sample_librispeech_len(&mut self.rng),
                };
                return Arrival { at: secs(self.t_s), len_s };
            }
        }
    }

    pub fn take(&mut self, n: usize) -> Vec<Arrival> {
        (0..n).map(|_| self.next()).collect()
    }

    /// The generator's rate profile.
    pub fn profile(&self) -> &RateProfile {
        &self.profile
    }
}

/// How to rescale a [`ReplayTrace`]'s timeline (see
/// [`ReplayTrace::rescaled`]). The first three re-time every arrival;
/// [`Rescale::Thin`] drops arrivals without moving the survivors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Rescale {
    /// Multiply the offered rate by this factor by compressing (or
    /// stretching) the timeline. The arrival *pattern* (burst structure,
    /// diurnal shape) is preserved.
    Factor(f64),
    /// [`Rescale::Factor`] chosen to hit a target mean rate, queries/s.
    ToQps(f64),
    /// Stretch/compress the timeline so the trace spans this many
    /// seconds (e.g. to align a recorded day onto a simulated horizon).
    ToDuration(f64),
    /// Deterministically thin to a ~`qps` mean WITHOUT moving the
    /// surviving timestamps: each arrival is kept i.i.d. with
    /// probability `qps / mean_qps()`, so the burst/diurnal shape and
    /// the timeline stay intact. A target at or above the current mean
    /// keeps everything — replay cannot invent arrivals.
    Thin {
        /// Target mean rate, queries/s.
        qps: f64,
        /// Seed for the keep/drop filter.
        seed: u64,
    },
}

/// A recorded arrival-timestamp trace for replay (sorted seconds from
/// trace start). Replay feeds the cluster DES the *exact* recorded
/// arrival process — Poisson/MMPP synthesis matches first moments but
/// not the autocorrelation structure real fleets see.
///
/// ```
/// use preba::workload::{ReplayTrace, Rescale};
///
/// let t = ReplayTrace::from_csv("# header\n0.0\n0.5\n1.0\n").unwrap();
/// assert_eq!(t.len(), 3);
/// // Rate-scaling knob: 2× the rate = timestamps squeezed 2×.
/// let fast = t.rescaled(Rescale::Factor(2.0));
/// assert!((fast.duration_s() - 0.5).abs() < 1e-12);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayTrace {
    at_s: Vec<f64>,
}

impl ReplayTrace {
    /// Build from raw timestamps (seconds; sorted internally). Errors on
    /// an empty list or non-finite/negative entries.
    pub fn new(mut at_s: Vec<f64>) -> anyhow::Result<ReplayTrace> {
        anyhow::ensure!(!at_s.is_empty(), "empty trace");
        for &t in &at_s {
            anyhow::ensure!(t.is_finite() && t >= 0.0, "bad trace timestamp {t}");
        }
        at_s.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
        Ok(ReplayTrace { at_s })
    }

    pub fn len(&self) -> usize {
        self.at_s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.at_s.is_empty()
    }

    /// Trace span: the last arrival's timestamp, seconds.
    pub fn duration_s(&self) -> f64 {
        *self.at_s.last().expect("non-empty")
    }

    /// Mean offered rate over the trace span, queries/s.
    pub fn mean_qps(&self) -> f64 {
        self.at_s.len() as f64 / self.duration_s().max(1e-9)
    }

    /// The raw timestamps, seconds from trace start (sorted).
    pub fn timestamps_s(&self) -> &[f64] {
        &self.at_s
    }

    /// Rescale the trace's timeline or rate (see [`Rescale`] for the
    /// four knobs). This subsumes the deprecated
    /// `scaled`/`scaled_to_qps`/`scaled_to_duration`/`thinned_to_qps`
    /// quartet behind one documented entry point.
    pub fn rescaled(&self, rescale: Rescale) -> ReplayTrace {
        match rescale {
            Rescale::Factor(factor) => {
                assert!(factor > 0.0, "rate scale must be positive");
                ReplayTrace { at_s: self.at_s.iter().map(|t| t / factor).collect() }
            }
            Rescale::ToQps(qps) => self.rescaled(Rescale::Factor(qps / self.mean_qps())),
            Rescale::ToDuration(duration_s) => {
                assert!(duration_s > 0.0, "duration must be positive");
                self.rescaled(Rescale::Factor(self.duration_s().max(1e-9) / duration_s))
            }
            Rescale::Thin { qps, seed } => {
                assert!(qps > 0.0, "target rate must be positive");
                let keep = qps / self.mean_qps();
                if keep >= 1.0 {
                    return self.clone();
                }
                let mut rng = Rng::new(seed ^ 0x7417_11ED);
                let kept: Vec<f64> =
                    self.at_s.iter().copied().filter(|_| rng.f64() < keep).collect();
                if kept.is_empty() {
                    // Degenerate target (keep-probability ~0): one arrival
                    // is the smallest non-empty replay.
                    return ReplayTrace { at_s: vec![self.at_s[0]] };
                }
                ReplayTrace { at_s: kept }
            }
        }
    }

    /// Multiply the offered rate by `factor`.
    #[deprecated(note = "use rescaled(Rescale::Factor(factor))")]
    pub fn scaled(&self, factor: f64) -> ReplayTrace {
        self.rescaled(Rescale::Factor(factor))
    }

    /// Scale to hit a target mean rate.
    #[deprecated(note = "use rescaled(Rescale::ToQps(qps))")]
    pub fn scaled_to_qps(&self, qps: f64) -> ReplayTrace {
        self.rescaled(Rescale::ToQps(qps))
    }

    /// Stretch/compress the timeline onto `duration_s`.
    #[deprecated(note = "use rescaled(Rescale::ToDuration(duration_s))")]
    pub fn scaled_to_duration(&self, duration_s: f64) -> ReplayTrace {
        self.rescaled(Rescale::ToDuration(duration_s))
    }

    /// Thin to a ~`qps` mean without re-timing survivors.
    #[deprecated(note = "use rescaled(Rescale::Thin { qps, seed })")]
    pub fn thinned_to_qps(&self, qps: f64, seed: u64) -> ReplayTrace {
        self.rescaled(Rescale::Thin { qps, seed })
    }

    /// Materialize the trace as DES arrivals for `model` (audio lengths
    /// sampled from the LibriSpeech distribution; vision inputs are 0 s).
    pub fn arrivals(&self, model: ModelId, rng: &mut Rng) -> Vec<Arrival> {
        self.at_s
            .iter()
            .map(|&t| {
                let len_s = match model.kind() {
                    ModelKind::Vision => 0.0,
                    ModelKind::Audio => sample_librispeech_len(rng),
                };
                Arrival { at: secs(t), len_s }
            })
            .collect()
    }

    /// Cursor-based [`ArrivalStream`](super::ArrivalStream) view of the
    /// trace: yields exactly what [`ReplayTrace::arrivals`] materializes
    /// (same order, same length draws from `rng`), one arrival at a time.
    pub fn cursor(&self, model: ModelId, rng: Rng) -> ReplayCursor {
        ReplayCursor::new(self, model, rng)
    }

    /// Parse a CSV of arrival timestamps: one record per line, first
    /// field is the timestamp in seconds. Blank lines, `#` comments, and
    /// a non-numeric header line are skipped. A recorded log must be
    /// time-ordered — a timestamp running backwards is corruption, not a
    /// formatting choice — so every rejection names its line.
    pub fn from_csv(text: &str) -> anyhow::Result<ReplayTrace> {
        let mut out: Vec<f64> = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let field = line.split(',').next().unwrap_or("").trim();
            match field.parse::<f64>() {
                Ok(t) => {
                    anyhow::ensure!(
                        t.is_finite() && t >= 0.0,
                        "trace CSV line {}: bad timestamp {t}",
                        lineno + 1
                    );
                    if let Some(&prev) = out.last() {
                        anyhow::ensure!(
                            t >= prev,
                            "trace CSV line {}: timestamp {t} runs backwards (previous {prev})",
                            lineno + 1
                        );
                    }
                    out.push(t);
                }
                // A header is only acceptable before any data row.
                Err(_) if out.is_empty() => continue,
                Err(_) => anyhow::bail!("trace CSV line {}: bad timestamp '{field}'", lineno + 1),
            }
        }
        anyhow::ensure!(!out.is_empty(), "trace CSV has no data rows");
        ReplayTrace::new(out)
    }

    /// Parse a JSON array of arrival timestamps — either a bare
    /// `[0.1, 0.2, ...]` or any object whose first `[...]` value is that
    /// array (e.g. `{"arrivals_s": [...]}`).
    pub fn from_json(text: &str) -> anyhow::Result<ReplayTrace> {
        let start = text.find('[').ok_or_else(|| anyhow::anyhow!("no JSON array in trace"))?;
        let end = text[start..]
            .find(']')
            .map(|e| start + e)
            .ok_or_else(|| anyhow::anyhow!("unterminated JSON array in trace"))?;
        let mut out: Vec<f64> = Vec::new();
        for (i, tok) in text[start + 1..end].split(',').enumerate() {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let t = tok
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("JSON trace element {i}: bad timestamp '{tok}'"))?;
            anyhow::ensure!(
                t.is_finite() && t >= 0.0,
                "JSON trace element {i}: bad timestamp {t}"
            );
            if let Some(&prev) = out.last() {
                anyhow::ensure!(
                    t >= prev,
                    "JSON trace element {i}: timestamp {t} runs backwards (previous {prev})"
                );
            }
            out.push(t);
        }
        anyhow::ensure!(!out.is_empty(), "JSON trace array is empty");
        ReplayTrace::new(out)
    }

    /// Load a trace file, dispatching on extension (`.json` → JSON,
    /// anything else → CSV).
    pub fn load(path: &str) -> anyhow::Result<ReplayTrace> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read trace '{path}': {e}"))?;
        let parsed = if path.ends_with(".json") {
            ReplayTrace::from_json(&text)
        } else {
            ReplayTrace::from_csv(&text)
        };
        parsed.map_err(|e| anyhow::anyhow!("trace '{path}': {e}"))
    }

    /// Bundled synthetic Azure-Functions-style trace: a diurnal envelope
    /// (two full cycles over `duration_s`, ±60%) modulated by an MMPP
    /// burst overlay (3× spikes with short dwell) — the shape of the
    /// public Azure Functions / LAQS arrival datasets, generated
    /// deterministically from `seed` so experiments need no dataset
    /// download. Mean rate ≈ `base_qps`.
    /// The state machine lives in [`SynthAzure`] (the streaming form, for
    /// traces too large to materialize); this collects it.
    pub fn synth_azure(seed: u64, duration_s: f64, base_qps: f64) -> ReplayTrace {
        let mut gen = SynthAzure::new(seed, duration_s, base_qps);
        let mut at_s = Vec::new();
        while let Some(t) = gen.next_ts() {
            at_s.push(t);
        }
        ReplayTrace::new(at_s).expect("synthetic trace is non-empty")
    }
}

/// Windowed arrival-rate estimate of a trace (diagnostics / tests).
pub fn windowed_rates(arrivals: &[Arrival], window: Nanos) -> Vec<f64> {
    if arrivals.is_empty() {
        return Vec::new();
    }
    let horizon = arrivals.last().unwrap().at;
    let n_windows = (horizon / window + 1) as usize;
    let mut counts = vec![0u64; n_windows];
    for a in arrivals {
        counts[(a.at / window) as usize] += 1;
    }
    let w_s = window as f64 * 1e-9;
    counts.into_iter().map(|c| c as f64 / w_s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::to_secs;

    #[test]
    fn constant_matches_poisson_mean() {
        let mut g = TraceGen::new(
            ModelId::MobileNet,
            RateProfile::Constant { qps: 200.0 },
            Rng::new(1),
        );
        let a = g.take(20_000);
        let rate = a.len() as f64 / to_secs(a.last().unwrap().at);
        assert!((rate / 200.0 - 1.0).abs() < 0.05, "rate={rate}");
    }

    #[test]
    fn diurnal_rate_oscillates() {
        let profile = RateProfile::Diurnal {
            base_qps: 100.0,
            amplitude: 0.8,
            period_s: 20.0,
            phase_frac: 0.0,
        };
        let mut g = TraceGen::new(ModelId::MobileNet, profile, Rng::new(2));
        let a = g.take(30_000);
        let rates = windowed_rates(&a, secs(2.0));
        let max = rates.iter().cloned().fold(0.0, f64::max);
        let min = rates
            .iter()
            .skip(1)
            .take(rates.len().saturating_sub(2))
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(max > 140.0, "max window rate {max}");
        assert!(min < 60.0, "min window rate {min}");
    }

    #[test]
    fn bursty_mean_rate_matches_mmpp() {
        let profile = RateProfile::Bursty {
            quiet_qps: 20.0,
            burst_qps: 400.0,
            mean_quiet_s: 4.0,
            mean_burst_s: 1.0,
        };
        let expect = profile.mean_rate();
        assert!((expect - 96.0).abs() < 1e-9);
        let mut g = TraceGen::new(ModelId::CitriNet, profile, Rng::new(3));
        // Long trace: per-cycle arrival counts are dominated by the
        // exponential burst dwell, so the mean converges slowly (~9%
        // relative std at 40k arrivals).
        let a = g.take(150_000);
        let rate = a.len() as f64 / to_secs(a.last().unwrap().at);
        assert!((rate / expect - 1.0).abs() < 0.15, "rate={rate} expect={expect}");
    }

    #[test]
    fn bursty_has_heavy_rate_dispersion() {
        let profile = RateProfile::Bursty {
            quiet_qps: 20.0,
            burst_qps: 400.0,
            mean_quiet_s: 4.0,
            mean_burst_s: 1.0,
        };
        let mut g = TraceGen::new(ModelId::CitriNet, profile, Rng::new(4));
        let a = g.take(30_000);
        let rates = windowed_rates(&a, secs(1.0));
        let mean = rates.iter().sum::<f64>() / rates.len() as f64;
        let var =
            rates.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / rates.len() as f64;
        // Coefficient of variation far above Poisson's.
        assert!(var.sqrt() / mean > 0.8, "cv={}", var.sqrt() / mean);
    }

    #[test]
    fn anti_phase_profiles_peak_in_opposite_halves() {
        let a = RateProfile::Diurnal {
            base_qps: 100.0,
            amplitude: 0.8,
            period_s: 20.0,
            phase_frac: 0.0,
        };
        let b = RateProfile::Diurnal {
            base_qps: 100.0,
            amplitude: 0.8,
            period_s: 20.0,
            phase_frac: 0.5,
        };
        // Quarter-period: A at peak, B at trough; total constant.
        assert!(a.rate_at(5.0, false) > 170.0);
        assert!(b.rate_at(5.0, false) < 30.0);
        for t in [0.0, 3.0, 7.5, 12.0] {
            let total = a.rate_at(t, false) + b.rate_at(t, false);
            assert!((total - 200.0).abs() < 1e-6, "t={t}: {total}");
        }
    }

    #[test]
    fn named_profiles_resolve() {
        assert!(matches!(
            RateProfile::named("constant", 10.0),
            Some(RateProfile::Constant { qps }) if qps == 10.0
        ));
        let d = RateProfile::named("diurnal", 100.0).unwrap();
        assert!((d.mean_rate() - 100.0).abs() < 1e-9);
        let b = RateProfile::named("bursty", 100.0).unwrap();
        assert!(b.max_rate() > 2.0 * b.mean_rate());
        assert!(RateProfile::named("square-wave", 1.0).is_none());
    }

    #[test]
    fn replay_parses_csv_and_json() {
        let csv = ReplayTrace::from_csv("ts,extra\n# comment\n0.25,a\n0.5,b\n\n1.5,c\n").unwrap();
        assert_eq!(csv.len(), 3);
        assert!((csv.duration_s() - 1.5).abs() < 1e-12);
        let json = ReplayTrace::from_json("{\"arrivals_s\": [0.25, 0.5, 1.5]}").unwrap();
        assert_eq!(json, csv);
        // Programmatic construction sorts; the loaders demand order.
        assert_eq!(ReplayTrace::new(vec![0.5, 0.25, 1.5]).unwrap(), csv);
        assert!(ReplayTrace::new(vec![-1.0]).is_err());
        assert!(ReplayTrace::new(vec![f64::NAN]).is_err());
    }

    #[test]
    fn replay_loaders_reject_corrupt_fixtures_with_row_context() {
        // Malformed row: the error names the offending line and field.
        let err = ReplayTrace::from_csv("h1\n1.0\nnot-a-number\n").unwrap_err().to_string();
        assert!(err.contains("line 3") && err.contains("not-a-number"), "{err}");
        // Non-finite / negative timestamps, with line context.
        let err = ReplayTrace::from_csv("0.5\nnan\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(ReplayTrace::from_csv("0.5\n-2.0\n").is_err());
        // Out-of-order rows are corruption in a recorded log, not a
        // formatting choice.
        let err = ReplayTrace::from_csv("1.0\n0.5\n").unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains("backwards"), "{err}");
        let err = ReplayTrace::from_json("[1.0, 0.5]").unwrap_err().to_string();
        assert!(err.contains("element 1") && err.contains("backwards"), "{err}");
        // Bad JSON element, named by index.
        let err = ReplayTrace::from_json("[0.5, oops]").unwrap_err().to_string();
        assert!(err.contains("element 1") && err.contains("oops"), "{err}");
        // Empty / headers-only / array-less files.
        assert!(ReplayTrace::from_csv("").is_err());
        assert!(ReplayTrace::from_csv("# only comments\nts\n").is_err());
        assert!(ReplayTrace::from_json("[]").is_err());
        assert!(ReplayTrace::from_json("{}").is_err());
        // load(): errors carry the path for both unreadable and corrupt
        // files.
        let err = ReplayTrace::load("/nonexistent/trace.csv").unwrap_err().to_string();
        assert!(err.contains("/nonexistent/trace.csv"), "{err}");
    }

    #[test]
    fn replay_scaling_preserves_shape() {
        let t = ReplayTrace::new(vec![1.0, 2.0, 4.0, 8.0]).unwrap();
        let s = t.rescaled(Rescale::Factor(4.0));
        assert!((s.duration_s() - 2.0).abs() < 1e-12);
        assert!((s.mean_qps() - 4.0 * t.mean_qps()).abs() < 1e-9);
        let to = t.rescaled(Rescale::ToQps(10.0));
        assert!((to.mean_qps() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn replay_duration_fit_and_thinning_preserve_the_timeline() {
        let t = ReplayTrace::new((1..=400).map(|i| i as f64 * 0.01).collect()).unwrap();
        let fit = t.rescaled(Rescale::ToDuration(2.0));
        assert!((fit.duration_s() - 2.0).abs() < 1e-9);
        assert_eq!(fit.len(), t.len());
        // Thinning halves the rate without re-timing survivors: every
        // kept timestamp exists in the source.
        let half = Rescale::Thin { qps: 0.5 * t.mean_qps(), seed: 7 };
        let thin = t.rescaled(half);
        assert!(thin.len() < t.len());
        assert!(thin.len() > t.len() / 4, "thinning kept {} of {}", thin.len(), t.len());
        assert!((thin.duration_s() - t.duration_s()).abs() < 0.2 * t.duration_s());
        assert_eq!(thin, t.rescaled(half), "thinning not seeded");
        // At or above the source rate, replay cannot invent arrivals.
        assert_eq!(t.rescaled(Rescale::Thin { qps: 10.0 * t.mean_qps(), seed: 7 }), t);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_rescale_shims_delegate_to_rescaled() {
        let t = ReplayTrace::new((1..=50).map(|i| i as f64 * 0.1).collect()).unwrap();
        assert_eq!(t.scaled(2.0), t.rescaled(Rescale::Factor(2.0)));
        assert_eq!(t.scaled_to_qps(7.0), t.rescaled(Rescale::ToQps(7.0)));
        assert_eq!(t.scaled_to_duration(3.0), t.rescaled(Rescale::ToDuration(3.0)));
        assert_eq!(t.thinned_to_qps(2.0, 9), t.rescaled(Rescale::Thin { qps: 2.0, seed: 9 }));
    }

    #[test]
    fn replay_arrivals_are_ordered_and_typed() {
        let t = ReplayTrace::new(vec![0.5, 0.1, 0.9]).unwrap();
        let vision = t.arrivals(ModelId::MobileNet, &mut Rng::new(1));
        assert_eq!(vision.len(), 3);
        assert!(vision.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(vision.iter().all(|a| a.len_s == 0.0));
        let audio = t.arrivals(ModelId::CitriNet, &mut Rng::new(1));
        assert!(audio.iter().all(|a| a.len_s >= 1.0));
        // Replay is deterministic given the same rng seed.
        assert_eq!(
            audio.iter().map(|a| a.at).collect::<Vec<_>>(),
            t.arrivals(ModelId::CitriNet, &mut Rng::new(1))
                .iter()
                .map(|a| a.at)
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn synth_azure_is_deterministic_diurnal_and_bursty() {
        let a = ReplayTrace::synth_azure(7, 40.0, 300.0);
        let b = ReplayTrace::synth_azure(7, 40.0, 300.0);
        assert_eq!(a, b);
        assert!(ReplayTrace::synth_azure(8, 40.0, 300.0) != a, "seed ignored");
        // Mean rate lands near the requested base.
        assert!((a.mean_qps() / 300.0 - 1.0).abs() < 0.25, "mean={}", a.mean_qps());
        // Diurnal envelope: the peak window rate well above the trough's.
        let arrivals = a.arrivals(ModelId::MobileNet, &mut Rng::new(2));
        let rates = windowed_rates(&arrivals, secs(2.0));
        let max = rates.iter().cloned().fold(0.0, f64::max);
        let min = rates
            .iter()
            .skip(1)
            .take(rates.len().saturating_sub(2))
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(max > 2.0 * min.max(1.0), "max={max} min={min}");
    }

    #[test]
    fn arrivals_strictly_ordered() {
        for profile in [
            RateProfile::Constant { qps: 50.0 },
            RateProfile::Diurnal {
                base_qps: 50.0,
                amplitude: 0.5,
                period_s: 10.0,
                phase_frac: 0.0,
            },
        ] {
            let mut g = TraceGen::new(ModelId::SqueezeNet, profile, Rng::new(5));
            let a = g.take(2000);
            for w in a.windows(2) {
                assert!(w[1].at >= w[0].at);
            }
        }
    }
}
