//! Non-stationary traffic: real inference servers see diurnal cycles and
//! bursts, not a constant-rate Poisson stream (paper §3.2: "input traffic
//! patterns are constantly changing with varying traffic intensities").
//!
//! Three generators on top of the Poisson thinning method:
//! * [`RateProfile::Constant`] — the MLPerf-server baseline.
//! * [`RateProfile::Diurnal`] — sinusoidal day/night swing.
//! * [`RateProfile::Bursty`] — Markov-modulated Poisson (quiet/burst
//!   states), the adversarial case for a batching system: bursts fill
//!   batches instantly while quiet periods leave requests waiting on
//!   `Time_queue`.

use crate::clock::{secs, Nanos};
use crate::models::{ModelId, ModelKind};
use crate::util::Rng;

use super::{sample_librispeech_len, Arrival};

/// Time-varying offered-rate profile, queries/s at time `t`.
#[derive(Debug, Clone)]
pub enum RateProfile {
    /// Fixed rate.
    Constant { qps: f64 },
    /// `base * (1 + amplitude * sin(2π (t/period + phase)))`. `phase_frac`
    /// shifts the cycle (0.5 = anti-phase — two tenants peaking in
    /// opposite halves of the day, the multi-tenant reconfig scenario).
    Diurnal { base_qps: f64, amplitude: f64, period_s: f64, phase_frac: f64 },
    /// Two-state MMPP: quiet rate / burst rate with exponential dwell
    /// times.
    Bursty {
        quiet_qps: f64,
        burst_qps: f64,
        mean_quiet_s: f64,
        mean_burst_s: f64,
    },
}

impl RateProfile {
    /// Instantaneous rate at `t_s` (burst state handled by the generator).
    pub fn rate_at(&self, t_s: f64, in_burst: bool) -> f64 {
        match self {
            RateProfile::Constant { qps } => *qps,
            RateProfile::Diurnal { base_qps, amplitude, period_s, phase_frac } => {
                let angle = 2.0 * std::f64::consts::PI * (t_s / period_s + phase_frac);
                base_qps * (1.0 + amplitude * angle.sin())
            }
            RateProfile::Bursty { quiet_qps, burst_qps, .. } => {
                if in_burst {
                    *burst_qps
                } else {
                    *quiet_qps
                }
            }
        }
        .max(1e-6)
    }

    /// Upper bound of the rate (for Poisson thinning).
    pub fn max_rate(&self) -> f64 {
        match self {
            RateProfile::Constant { qps } => *qps,
            RateProfile::Diurnal { base_qps, amplitude, .. } => base_qps * (1.0 + amplitude.abs()),
            RateProfile::Bursty { quiet_qps, burst_qps, .. } => quiet_qps.max(*burst_qps),
        }
    }

    /// Named profile shapes around a base rate (CLI `--profile` and the
    /// reconfiguration experiments' defaults).
    pub fn named(kind: &str, base_qps: f64) -> Option<RateProfile> {
        match kind {
            "constant" => Some(RateProfile::Constant { qps: base_qps }),
            "diurnal" => Some(RateProfile::Diurnal {
                base_qps,
                amplitude: 0.7,
                period_s: 30.0,
                phase_frac: 0.0,
            }),
            "bursty" => Some(RateProfile::Bursty {
                quiet_qps: 0.25 * base_qps,
                burst_qps: 2.5 * base_qps,
                mean_quiet_s: 4.0,
                mean_burst_s: 1.5,
            }),
            _ => None,
        }
    }

    /// Long-run mean rate.
    pub fn mean_rate(&self) -> f64 {
        match self {
            RateProfile::Constant { qps } => *qps,
            RateProfile::Diurnal { base_qps, .. } => *base_qps,
            RateProfile::Bursty { quiet_qps, burst_qps, mean_quiet_s, mean_burst_s } => {
                (quiet_qps * mean_quiet_s + burst_qps * mean_burst_s)
                    / (mean_quiet_s + mean_burst_s)
            }
        }
    }
}

/// Non-stationary arrival generator (thinning / state-switching).
#[derive(Debug)]
pub struct TraceGen {
    model: ModelId,
    profile: RateProfile,
    rng: Rng,
    t_s: f64,
    in_burst: bool,
    /// Next burst/quiet state switch (bursty profile only).
    next_switch_s: f64,
}

impl TraceGen {
    pub fn new(model: ModelId, profile: RateProfile, mut rng: Rng) -> TraceGen {
        let next_switch_s = match &profile {
            RateProfile::Bursty { mean_quiet_s, .. } => rng.exp(1.0 / mean_quiet_s),
            _ => f64::INFINITY,
        };
        TraceGen { model, profile, rng, t_s: 0.0, in_burst: false, next_switch_s }
    }

    fn advance_state(&mut self) {
        if let RateProfile::Bursty { mean_quiet_s, mean_burst_s, .. } = self.profile {
            while self.t_s >= self.next_switch_s {
                self.in_burst = !self.in_burst;
                let dwell =
                    if self.in_burst { mean_burst_s } else { mean_quiet_s };
                self.next_switch_s += self.rng.exp(1.0 / dwell);
            }
        }
    }

    /// Next arrival via Poisson thinning against `max_rate`.
    pub fn next(&mut self) -> Arrival {
        let lambda_max = self.profile.max_rate();
        loop {
            self.t_s += self.rng.exp(lambda_max);
            self.advance_state();
            let lambda = self.profile.rate_at(self.t_s, self.in_burst);
            if self.rng.f64() <= lambda / lambda_max {
                let len_s = match self.model.kind() {
                    ModelKind::Vision => 0.0,
                    ModelKind::Audio => sample_librispeech_len(&mut self.rng),
                };
                return Arrival { at: secs(self.t_s), len_s };
            }
        }
    }

    pub fn take(&mut self, n: usize) -> Vec<Arrival> {
        (0..n).map(|_| self.next()).collect()
    }
}

/// Windowed arrival-rate estimate of a trace (diagnostics / tests).
pub fn windowed_rates(arrivals: &[Arrival], window: Nanos) -> Vec<f64> {
    if arrivals.is_empty() {
        return Vec::new();
    }
    let horizon = arrivals.last().unwrap().at;
    let n_windows = (horizon / window + 1) as usize;
    let mut counts = vec![0u64; n_windows];
    for a in arrivals {
        counts[(a.at / window) as usize] += 1;
    }
    let w_s = window as f64 * 1e-9;
    counts.into_iter().map(|c| c as f64 / w_s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::to_secs;

    #[test]
    fn constant_matches_poisson_mean() {
        let mut g = TraceGen::new(
            ModelId::MobileNet,
            RateProfile::Constant { qps: 200.0 },
            Rng::new(1),
        );
        let a = g.take(20_000);
        let rate = a.len() as f64 / to_secs(a.last().unwrap().at);
        assert!((rate / 200.0 - 1.0).abs() < 0.05, "rate={rate}");
    }

    #[test]
    fn diurnal_rate_oscillates() {
        let profile = RateProfile::Diurnal {
            base_qps: 100.0,
            amplitude: 0.8,
            period_s: 20.0,
            phase_frac: 0.0,
        };
        let mut g = TraceGen::new(ModelId::MobileNet, profile, Rng::new(2));
        let a = g.take(30_000);
        let rates = windowed_rates(&a, secs(2.0));
        let max = rates.iter().cloned().fold(0.0, f64::max);
        let min = rates
            .iter()
            .skip(1)
            .take(rates.len().saturating_sub(2))
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(max > 140.0, "max window rate {max}");
        assert!(min < 60.0, "min window rate {min}");
    }

    #[test]
    fn bursty_mean_rate_matches_mmpp() {
        let profile = RateProfile::Bursty {
            quiet_qps: 20.0,
            burst_qps: 400.0,
            mean_quiet_s: 4.0,
            mean_burst_s: 1.0,
        };
        let expect = profile.mean_rate();
        assert!((expect - 96.0).abs() < 1e-9);
        let mut g = TraceGen::new(ModelId::CitriNet, profile, Rng::new(3));
        // Long trace: per-cycle arrival counts are dominated by the
        // exponential burst dwell, so the mean converges slowly (~9%
        // relative std at 40k arrivals).
        let a = g.take(150_000);
        let rate = a.len() as f64 / to_secs(a.last().unwrap().at);
        assert!((rate / expect - 1.0).abs() < 0.15, "rate={rate} expect={expect}");
    }

    #[test]
    fn bursty_has_heavy_rate_dispersion() {
        let profile = RateProfile::Bursty {
            quiet_qps: 20.0,
            burst_qps: 400.0,
            mean_quiet_s: 4.0,
            mean_burst_s: 1.0,
        };
        let mut g = TraceGen::new(ModelId::CitriNet, profile, Rng::new(4));
        let a = g.take(30_000);
        let rates = windowed_rates(&a, secs(1.0));
        let mean = rates.iter().sum::<f64>() / rates.len() as f64;
        let var =
            rates.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / rates.len() as f64;
        // Coefficient of variation far above Poisson's.
        assert!(var.sqrt() / mean > 0.8, "cv={}", var.sqrt() / mean);
    }

    #[test]
    fn anti_phase_profiles_peak_in_opposite_halves() {
        let a = RateProfile::Diurnal {
            base_qps: 100.0,
            amplitude: 0.8,
            period_s: 20.0,
            phase_frac: 0.0,
        };
        let b = RateProfile::Diurnal {
            base_qps: 100.0,
            amplitude: 0.8,
            period_s: 20.0,
            phase_frac: 0.5,
        };
        // Quarter-period: A at peak, B at trough; total constant.
        assert!(a.rate_at(5.0, false) > 170.0);
        assert!(b.rate_at(5.0, false) < 30.0);
        for t in [0.0, 3.0, 7.5, 12.0] {
            let total = a.rate_at(t, false) + b.rate_at(t, false);
            assert!((total - 200.0).abs() < 1e-6, "t={t}: {total}");
        }
    }

    #[test]
    fn named_profiles_resolve() {
        assert!(matches!(
            RateProfile::named("constant", 10.0),
            Some(RateProfile::Constant { qps }) if qps == 10.0
        ));
        let d = RateProfile::named("diurnal", 100.0).unwrap();
        assert!((d.mean_rate() - 100.0).abs() < 1e-9);
        let b = RateProfile::named("bursty", 100.0).unwrap();
        assert!(b.max_rate() > 2.0 * b.mean_rate());
        assert!(RateProfile::named("square-wave", 1.0).is_none());
    }

    #[test]
    fn arrivals_strictly_ordered() {
        for profile in [
            RateProfile::Constant { qps: 50.0 },
            RateProfile::Diurnal {
                base_qps: 50.0,
                amplitude: 0.5,
                period_s: 10.0,
                phase_frac: 0.0,
            },
        ] {
            let mut g = TraceGen::new(ModelId::SqueezeNet, profile, Rng::new(5));
            let a = g.take(2000);
            for w in a.windows(2) {
                assert!(w[1].at >= w[0].at);
            }
        }
    }
}
