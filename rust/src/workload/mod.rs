//! Workload synthesis (paper §5 "Input query modeling").
//!
//! * Poisson arrivals (MLPerf-server style) with configurable rate
//!   ([`QueryGen`]).
//! * Non-stationary traffic ([`trace`]): diurnal and MMPP-bursty rate
//!   profiles ([`RateProfile`]/[`TraceGen`]), plus recorded-trace replay
//!   ([`ReplayTrace`]) with CSV/JSON loading, a rate-rescaling knob
//!   ([`Rescale`]), and a bundled Azure-style synthetic generator.
//! * Pull-based streaming ([`stream`]): the [`ArrivalStream`] seam the
//!   DES drivers pull arrivals through lazily, with chunked CSV/JSON
//!   file readers and a tenant-attachable [`StreamSpec`] so
//!   multi-million-row traces never materialize.
//! * Audio lengths drawn from a LibriSpeech-shaped distribution
//!   (Fig 13): a lognormal body peaking ~12-14 s with a short-utterance
//!   mode, clipped to 1-25 s. Vision inputs are fixed-size.
//! * Input synthesis for the real driver: DCT-coefficient images and
//!   sinusoid-mixture PCM audio.
//!
//! Every generator draws from the crate's deterministic [`Rng`], so a
//! workload is a pure function of its seed:
//!
//! ```
//! use preba::models::ModelId;
//! use preba::util::Rng;
//! use preba::workload::QueryGen;
//!
//! let arrivals = QueryGen::new(ModelId::MobileNet, 100.0, Rng::new(1)).take(50);
//! assert_eq!(arrivals.len(), 50);
//! assert!(arrivals.windows(2).all(|w| w[0].at <= w[1].at));
//! // Same seed, same stream.
//! let again = QueryGen::new(ModelId::MobileNet, 100.0, Rng::new(1)).take(50);
//! assert_eq!(arrivals.iter().map(|a| a.at).collect::<Vec<_>>(),
//!            again.iter().map(|a| a.at).collect::<Vec<_>>());
//! ```

pub mod stream;
pub mod trace;

pub use stream::{ArrivalStream, Bounded, ReplayCursor, StreamSource, StreamSpec, SynthAzure};
pub use trace::{RateProfile, ReplayTrace, Rescale, TraceGen};

use crate::clock::{secs, Nanos};
use crate::models::{ModelId, ModelKind};
use crate::util::Rng;

/// A generated arrival: (time, audio length seconds or 0).
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    pub at: Nanos,
    pub len_s: f64,
}

/// Poisson arrival process with per-request input lengths.
#[derive(Debug)]
pub struct QueryGen {
    model: ModelId,
    rate_qps: f64,
    rng: Rng,
    next_at_s: f64,
}

impl QueryGen {
    pub fn new(model: ModelId, rate_qps: f64, rng: Rng) -> QueryGen {
        assert!(rate_qps > 0.0);
        QueryGen { model, rate_qps, rng, next_at_s: 0.0 }
    }

    /// Next arrival (exponential inter-arrival gaps).
    pub fn next(&mut self) -> Arrival {
        self.next_at_s += self.rng.exp(self.rate_qps);
        let len_s = match self.model.kind() {
            ModelKind::Vision => 0.0,
            ModelKind::Audio => sample_librispeech_len(&mut self.rng),
        };
        Arrival { at: secs(self.next_at_s), len_s }
    }

    /// Generate the first `n` arrivals.
    pub fn take(&mut self, n: usize) -> Vec<Arrival> {
        (0..n).map(|_| self.next()).collect()
    }

    pub fn rate(&self) -> f64 {
        self.rate_qps
    }
}

/// LibriSpeech test-clean duration distribution (Fig 13): most mass
/// between 2 and 17 s, peak around 12-14 s, few clips >20 s. We use a
/// two-component mixture clipped to [1, 25]:
/// 20% lognormal(ln 4.0, 0.45) (short utterances) +
/// 80% normal(12.5, 4.0) (the broad body).
pub fn sample_librispeech_len(rng: &mut Rng) -> f64 {
    let x = if rng.f64() < 0.20 {
        rng.lognormal(4.0f64.ln(), 0.45)
    } else {
        12.5 + 4.0 * rng.normal()
    };
    x.clamp(1.0, 25.0)
}

/// Synthesize a quantized-DCT-coefficient image (the decode stage's
/// input) with plausible spectral decay; HWC row-major.
pub fn synth_image_coeffs(h: usize, w: usize, ch: usize, rng: &mut Rng) -> Vec<f32> {
    let mut out = vec![0f32; h * w * ch];
    for by in (0..h).step_by(8) {
        for bx in (0..w).step_by(8) {
            for c in 0..ch {
                // DC + decaying AC coefficients, mostly zero at high freq
                // (what entropy decoding of a real JPEG produces).
                for i in 0..8.min(h - by) {
                    for j in 0..8.min(w - bx) {
                        let decay = 1.0 / (1.0 + (i + j) as f64 * 1.5);
                        let v = if i == 0 && j == 0 {
                            rng.range_f64(-40.0, 40.0)
                        } else if rng.f64() < decay {
                            rng.range_f64(-8.0, 8.0) * decay
                        } else {
                            0.0
                        };
                        out[((by + i) * w + bx + j) * ch + c] = v as f32;
                    }
                }
            }
        }
    }
    out
}

/// Synthesize `len_s` seconds of 16 kHz PCM: a mixture of tones + noise
/// (speech-ish spectral content for the mel pipeline).
pub fn synth_pcm(len_s: f64, rng: &mut Rng) -> Vec<f32> {
    let n = (len_s * 16_000.0) as usize;
    let f0 = rng.range_f64(110.0, 280.0); // fundamental
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let t = i as f64 / 16_000.0;
        let mut v = 0.0;
        for (k, amp) in [(1.0, 0.5), (2.0, 0.25), (3.0, 0.12), (5.0, 0.06)] {
            v += amp * (2.0 * std::f64::consts::PI * f0 * k * t).sin();
        }
        v += 0.05 * rng.normal();
        out.push(v as f32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::to_secs;

    #[test]
    fn poisson_rate_converges() {
        let mut g = QueryGen::new(ModelId::MobileNet, 100.0, Rng::new(1));
        let arrivals = g.take(20_000);
        let span = to_secs(arrivals.last().unwrap().at);
        let rate = arrivals.len() as f64 / span;
        assert!((rate / 100.0 - 1.0).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn arrivals_monotonic() {
        let mut g = QueryGen::new(ModelId::CitriNet, 50.0, Rng::new(2));
        let a = g.take(1000);
        for w in a.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
    }

    #[test]
    fn vision_lengths_zero_audio_positive() {
        let mut gv = QueryGen::new(ModelId::SqueezeNet, 10.0, Rng::new(3));
        assert!(gv.take(100).iter().all(|a| a.len_s == 0.0));
        let mut ga = QueryGen::new(ModelId::CitriNet, 10.0, Rng::new(3));
        assert!(ga.take(100).iter().all(|a| a.len_s >= 1.0));
    }

    #[test]
    fn librispeech_distribution_shape() {
        let mut rng = Rng::new(5);
        let xs: Vec<f64> = (0..50_000).map(|_| sample_librispeech_len(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        // Fig 13: bulk between 2-17 s, mean ~ 10-12 s.
        assert!((8.0..13.0).contains(&mean), "mean={mean}");
        assert!(xs.iter().all(|&x| (1.0..=25.0).contains(&x)));
        let frac_short = xs.iter().filter(|&&x| x < 5.0).count() as f64 / xs.len() as f64;
        assert!((0.1..0.45).contains(&frac_short), "short frac={frac_short}");
        let frac_long = xs.iter().filter(|&&x| x > 20.0).count() as f64 / xs.len() as f64;
        assert!(frac_long < 0.1, "long frac={frac_long}");
    }

    #[test]
    fn image_coeffs_have_dc_energy() {
        let mut rng = Rng::new(7);
        let img = synth_image_coeffs(96, 96, 3, &mut rng);
        assert_eq!(img.len(), 96 * 96 * 3);
        // Non-trivial content, finite values.
        let energy: f32 = img.iter().map(|v| v * v).sum();
        assert!(energy > 0.0);
        assert!(img.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn pcm_length_and_range() {
        let mut rng = Rng::new(9);
        let pcm = synth_pcm(2.5, &mut rng);
        assert_eq!(pcm.len(), 40_000);
        assert!(pcm.iter().all(|v| v.abs() < 2.0));
    }
}
