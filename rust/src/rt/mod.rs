//! Minimal thread-pool runtime (in lieu of `tokio`, absent offline).
//!
//! The real-PJRT serving driver needs: (a) a pool of worker threads, one
//! per vGPU, each owning its compiled executables; (b) bounded MPSC
//! channels with blocking send/recv for backpressure; (c) a timer thread
//! for batching deadlines. std gives us threads and channels; this module
//! adds the pool lifecycle and a bounded channel wrapper with metrics.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Bounded MPSC channel pair with depth metrics (for backpressure studies).
pub struct Channel<T> {
    tx: SyncSender<T>,
    depth: Arc<Mutex<usize>>,
}

pub struct ChannelRx<T> {
    rx: Receiver<T>,
    depth: Arc<Mutex<usize>>,
}

/// Create a bounded channel of capacity `cap`.
pub fn channel<T>(cap: usize) -> (Channel<T>, ChannelRx<T>) {
    let (tx, rx) = sync_channel(cap);
    let depth = Arc::new(Mutex::new(0));
    (Channel { tx, depth: depth.clone() }, ChannelRx { rx, depth })
}

impl<T> Channel<T> {
    /// Blocking send (applies backpressure when full).
    pub fn send(&self, v: T) -> anyhow::Result<()> {
        self.tx.send(v).map_err(|_| anyhow::anyhow!("channel closed"))?;
        *self.depth.lock().unwrap() += 1;
        Ok(())
    }

    /// Non-blocking send; returns the value back if the queue is full.
    pub fn try_send(&self, v: T) -> Result<(), T> {
        match self.tx.try_send(v) {
            Ok(()) => {
                *self.depth.lock().unwrap() += 1;
                Ok(())
            }
            Err(TrySendError::Full(v)) | Err(TrySendError::Disconnected(v)) => Err(v),
        }
    }

    pub fn depth(&self) -> usize {
        *self.depth.lock().unwrap()
    }
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Channel { tx: self.tx.clone(), depth: self.depth.clone() }
    }
}

impl<T> ChannelRx<T> {
    /// Blocking receive; `None` when all senders dropped.
    pub fn recv(&self) -> Option<T> {
        match self.rx.recv() {
            Ok(v) => {
                let mut d = self.depth.lock().unwrap();
                *d = d.saturating_sub(1);
                Some(v)
            }
            Err(_) => None,
        }
    }

    /// Receive with timeout.
    pub fn recv_timeout(&self, dur: std::time::Duration) -> Option<T> {
        match self.rx.recv_timeout(dur) {
            Ok(v) => {
                let mut d = self.depth.lock().unwrap();
                *d = d.saturating_sub(1);
                Some(v)
            }
            Err(_) => None,
        }
    }
}

/// A named pool of worker threads, joined on drop.
pub struct WorkerPool {
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new() -> Self {
        WorkerPool { handles: Vec::new() }
    }

    /// Spawn a named worker.
    pub fn spawn<F: FnOnce() + Send + 'static>(&mut self, name: &str, f: F) {
        let h = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .expect("spawn worker");
        self.handles.push(h);
    }

    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Wait for all workers to finish.
    pub fn join(mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn channel_roundtrip_and_depth() {
        let (tx, rx) = channel::<u32>(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(tx.depth(), 2);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(tx.depth(), 0);
    }

    #[test]
    fn try_send_full() {
        let (tx, _rx) = channel::<u32>(1);
        assert!(tx.try_send(1).is_ok());
        assert_eq!(tx.try_send(2), Err(2));
    }

    #[test]
    fn recv_none_when_closed() {
        let (tx, rx) = channel::<u32>(1);
        drop(tx);
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn pool_runs_work() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut pool = WorkerPool::new();
        for i in 0..4 {
            let c = counter.clone();
            pool.spawn(&format!("w{i}"), move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn fan_in_many_producers() {
        let (tx, rx) = channel::<usize>(64);
        let mut pool = WorkerPool::new();
        for i in 0..8 {
            let tx = tx.clone();
            pool.spawn("prod", move || {
                for j in 0..10 {
                    tx.send(i * 10 + j).unwrap();
                }
            });
        }
        drop(tx);
        let mut got = Vec::new();
        while let Some(v) = rx.recv() {
            got.push(v);
        }
        pool.join();
        got.sort_unstable();
        assert_eq!(got, (0..80).collect::<Vec<_>>());
    }
}
