//! System power model (paper §6.2, Fig 20).
//!
//! Component power = idle floor + (TDP - idle) × utilization. The paper's
//! observations this must reproduce:
//! * PREBA cuts CPU power ~35.4% on average (preprocessing off the host);
//! * PREBA *raises* GPU power (~2.8× for audio) because utilization rises;
//! * the DPU adds FPGA power but net energy-efficiency improves ~3.5×.

use crate::config::PowerConfig;

/// Per-component and total watts.
#[derive(Debug, Clone, Copy, Default)]
pub struct PowerBreakdown {
    pub cpu_w: f64,
    pub gpu_w: f64,
    pub fpga_w: f64,
    pub base_w: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.cpu_w + self.gpu_w + self.fpga_w + self.base_w
    }
}

/// Utilization-weighted power model.
#[derive(Debug, Clone)]
pub struct PowerModel {
    cfg: PowerConfig,
}

impl PowerModel {
    pub fn new(cfg: &PowerConfig) -> PowerModel {
        PowerModel { cfg: cfg.clone() }
    }

    /// System power given component utilizations in [0,1].
    ///
    /// * `cpu_util` — host cores busy fraction (preprocessing + serving).
    /// * `gpu_util` — mean vGPU utilization × fraction of GPCs active.
    /// * `fpga_util` — `None` when no DPU is installed (baseline).
    pub fn power(&self, cpu_util: f64, gpu_util: f64, fpga_util: Option<f64>) -> PowerBreakdown {
        let c = &self.cfg;
        let scale = |tdp: f64, idle_frac: f64, u: f64| {
            tdp * (idle_frac + (1.0 - idle_frac) * u.clamp(0.0, 1.0))
        };
        PowerBreakdown {
            cpu_w: scale(c.cpu_tdp_w, c.cpu_idle_frac, cpu_util),
            gpu_w: scale(c.gpu_tdp_w, c.gpu_idle_frac, gpu_util),
            fpga_w: fpga_util.map_or(0.0, |u| scale(c.fpga_w, c.fpga_idle_frac, u)),
            base_w: c.server_base_w,
        }
    }

    /// Energy efficiency: queries per joule (= QPS / W).
    pub fn qpj(&self, qps: f64, breakdown: &PowerBreakdown) -> f64 {
        if breakdown.total() <= 0.0 {
            0.0
        } else {
            qps / breakdown.total()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> PowerModel {
        PowerModel::new(&PowerConfig::default())
    }

    #[test]
    fn idle_floor_and_tdp_cap() {
        let m = model();
        let idle = m.power(0.0, 0.0, Some(0.0));
        assert!((idle.cpu_w - 180.0 * 0.35).abs() < 1e-9);
        assert!((idle.gpu_w - 400.0 * 0.20).abs() < 1e-9);
        let full = m.power(1.0, 1.0, Some(1.0));
        assert_eq!(full.cpu_w, 180.0);
        assert_eq!(full.gpu_w, 400.0);
        assert_eq!(full.fpga_w, 75.0);
        // clamps
        let over = m.power(5.0, 5.0, Some(5.0));
        assert_eq!(over.total(), full.total());
    }

    #[test]
    fn no_fpga_means_zero_fpga_power() {
        let m = model();
        assert_eq!(m.power(0.5, 0.5, None).fpga_w, 0.0);
    }

    #[test]
    fn preba_direction_of_change() {
        // Baseline: CPU pinned ~90%, GPU starved (~25% util).
        // PREBA: CPU light (~20%), GPU busy (~85%), FPGA on.
        let m = model();
        let base = m.power(0.90, 0.25, None);
        let preba = m.power(0.20, 0.85, Some(0.6));
        assert!(preba.cpu_w < base.cpu_w * 0.75, "CPU power should drop >25%");
        assert!(preba.gpu_w > base.gpu_w * 1.5, "GPU power should rise");
        // Efficiency: PREBA at ~4x the throughput wins despite more watts.
        let eff_base = m.qpj(1000.0, &base);
        let eff_preba = m.qpj(3700.0, &preba);
        assert!(eff_preba / eff_base > 2.0, "ratio={}", eff_preba / eff_base);
    }

    #[test]
    fn qpj_zero_guard() {
        let m = model();
        let bd = PowerBreakdown::default();
        assert_eq!(m.qpj(100.0, &bd), 0.0);
    }
}
