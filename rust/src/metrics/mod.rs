//! Serving metrics: latency breakdowns, throughput and energy counters.
//!
//! The power/energy/TCO *models* live in [`crate::energy`] (re-exported
//! here for compatibility); this module holds the per-run measurement
//! containers the DES drivers fill.

pub use crate::energy::{EnergyBreakdown, PowerBreakdown, PowerModel, TcoModel};

use crate::clock::{to_millis, to_secs, Nanos};
use crate::util::Summary;

/// Per-request latency breakdown (paper Fig 7 / Fig 19 stages).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LatencyParts {
    /// Wait + service in the preprocessing stage (CPU pool or DPU).
    pub preprocess: Nanos,
    /// Time in the batching queue (enqueue -> batch formed).
    pub batching: Nanos,
    /// Wait for a free vGPU after the batch formed.
    pub dispatch_wait: Nanos,
    /// Model execution on the vGPU.
    pub execution: Nanos,
}

impl LatencyParts {
    pub fn total(&self) -> Nanos {
        self.preprocess + self.batching + self.dispatch_wait + self.execution
    }
}

/// Collects per-request results for one measurement run.
#[derive(Debug, Default)]
pub struct RunStats {
    pub e2e_ms: Summary,
    pub preprocess_ms: Summary,
    pub batching_ms: Summary,
    pub dispatch_ms: Summary,
    pub execution_ms: Summary,
    pub batch_sizes: Summary,
    pub completed: u64,
    /// Post-warmup requests turned away with no capacity and never
    /// served (cluster admission accounting; latency summaries above
    /// exclude these).
    pub dropped: u64,
    /// Post-warmup requests that waited in an admission queue because no
    /// capacity was live at arrival (instead of being dropped outright).
    /// `deferred - deferred_served` of them still ended as `dropped`.
    pub deferred: u64,
    /// Deferred requests that were served once re-packing freed capacity
    /// — the traffic admission control converts from dropped to merely
    /// late. Always counted inside `completed` too.
    pub deferred_served: u64,
    /// Post-warmup requests lost to an injected fault: swallowed by a
    /// crash (retry budget exhausted, or no recovery at all) and never
    /// served. Terminal, disjoint from `completed` and `dropped`.
    pub timed_out: u64,
    /// Retry attempts issued for crash-lost requests (attempts, not
    /// requests: one request can contribute up to the retry budget).
    pub retries: u64,
    /// Hedged duplicates issued to a second replica after the routed
    /// group silently failed.
    pub hedges: u64,
    /// Completions that ran on a slowdown-degraded GPU (the fault's
    /// service-time multiplier was > 1 at dispatch). Counted inside
    /// `completed` too.
    pub served_degraded: u64,
    /// Total arrivals the driver injected for this measurement, warmup
    /// included. 0 for drivers that predate the accounting audit (the
    /// real-PJRT driver) — [`RunStats::audit`] is vacuous then.
    pub arrivals: u64,
    /// Arrivals whose terminal state fell inside the warmup and was
    /// therefore excluded from the counters above: completions skipped by
    /// the completion-order rule (`completed <= warmup`) plus drops /
    /// timeouts of warmup-indexed arrivals. Closes the conservation law
    /// checked by [`RunStats::audit`].
    pub warmup_skipped: u64,
    /// Integrated component energy over the run's horizon
    /// ([`crate::energy::EnergyModel`]); zero for drivers that do not
    /// integrate power (the real-PJRT driver).
    pub energy: EnergyBreakdown,
    /// Time of first/last completion (for measured throughput).
    first_done: Option<Nanos>,
    last_done: Option<Nanos>,
    /// Run horizon the driver observed (fallback throughput window when
    /// the completion window is degenerate — see [`RunStats::throughput_qps`]).
    horizon: Nanos,
}

impl RunStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request.
    pub fn record(&mut self, parts: LatencyParts, done_at: Nanos, batch_size: usize) {
        self.e2e_ms.add(to_millis(parts.total()));
        self.preprocess_ms.add(to_millis(parts.preprocess));
        self.batching_ms.add(to_millis(parts.batching));
        self.dispatch_ms.add(to_millis(parts.dispatch_wait));
        self.execution_ms.add(to_millis(parts.execution));
        self.batch_sizes.add(batch_size as f64);
        self.completed += 1;
        self.first_done = Some(self.first_done.map_or(done_at, |t| t.min(done_at)));
        self.last_done = Some(self.last_done.map_or(done_at, |t| t.max(done_at)));
    }

    /// Record the driver's run horizon. Used only as a fallback
    /// throughput window; calling it never changes the result for runs
    /// with a non-degenerate completion window.
    pub fn note_horizon(&mut self, horizon: Nanos) {
        self.horizon = self.horizon.max(horizon);
    }

    /// Measured goodput, queries/s: completions over the completion
    /// window. A degenerate window — a single completion, or every
    /// completion landing on one timestamp (tiny runs, perfectly batched
    /// bursts) — used to report 0.0, which poisoned any downstream ratio
    /// (0 qps/W with joules on the meter); it now falls back to the run
    /// horizon when the driver provided one.
    pub fn throughput_qps(&self) -> f64 {
        match (self.first_done, self.last_done) {
            (Some(a), Some(b)) if b > a && self.completed > 1 => {
                (self.completed - 1) as f64 / to_secs(b - a)
            }
            _ if self.completed > 0 && self.horizon > 0 => {
                self.completed as f64 / to_secs(self.horizon)
            }
            _ => 0.0,
        }
    }

    /// p95 end-to-end latency, ms (the paper's tail metric).
    pub fn p95_ms(&self) -> f64 {
        self.e2e_ms.p95()
    }

    /// p99 end-to-end latency, ms (the cluster experiments' fleet-tail
    /// metric — packing mistakes surface further out in the tail than the
    /// paper's single-GPU p95).
    pub fn p99_ms(&self) -> f64 {
        self.e2e_ms.p99()
    }

    /// Fraction of completed requests whose end-to-end latency exceeded
    /// `sla_ms` (the reconfiguration experiments' violation metric).
    pub fn sla_violation_frac(&self, sla_ms: f64) -> f64 {
        self.e2e_ms.frac_above(sla_ms)
    }

    /// Fraction of post-warmup demand that was actually served
    /// (`completed / (completed + dropped)`); 1.0 with no demand.
    pub fn served_frac(&self) -> f64 {
        let demand = self.completed + self.dropped;
        if demand == 0 {
            1.0
        } else {
            self.completed as f64 / demand as f64
        }
    }

    /// Availability under faults: the fraction of post-warmup demand that
    /// was served, with fault-timed-out requests counted against it
    /// (`completed / (completed + dropped + timed_out)`); 1.0 with no
    /// demand. Equals `served_frac` in fault-free runs.
    pub fn availability_frac(&self) -> f64 {
        let demand = self.completed + self.dropped + self.timed_out;
        if demand == 0 {
            1.0
        } else {
            self.completed as f64 / demand as f64
        }
    }

    /// Total integrated energy over the run, joules (0 when the driver
    /// does not integrate power).
    pub fn energy_j(&self) -> f64 {
        self.energy.total_j()
    }

    /// Mean energy per completed query, joules (0 with no completions).
    pub fn joules_per_query(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.energy_j() / self.completed as f64
        }
    }

    /// Energy efficiency, queries per joule — numerically identical to
    /// sustained QPS per watt (the paper's Perf/Watt metric), since both
    /// divide the same completion count by the same ∫power·dt.
    pub fn perf_per_watt(&self) -> f64 {
        let e = self.energy_j();
        if e <= 0.0 {
            0.0
        } else {
            self.completed as f64 / e
        }
    }

    /// Accounting conservation audit: every injected arrival must end in
    /// exactly one terminal bucket. With `arrivals` recorded (both DES
    /// drivers), checks
    /// `completed + dropped + timed_out + warmup_skipped == arrivals`
    /// plus the admission inequalities `deferred_served ≤ deferred ≤
    /// arrivals` and `deferred_served ≤ completed + warmup_skipped` (a
    /// deferred-then-served request completed, possibly inside warmup).
    /// Vacuously Ok when `arrivals == 0` (drivers that predate the audit).
    ///
    /// `warmup_skipped` is what makes the law exact: completions use a
    /// completion-ORDER warmup rule while drops/timeouts use an
    /// arrival-INDEX rule, so without it the terminal buckets would not
    /// sum to the post-warmup arrival count under mixed outcomes.
    pub fn audit(&self) -> anyhow::Result<()> {
        if self.arrivals == 0 {
            return Ok(());
        }
        let terminal = self.completed + self.dropped + self.timed_out + self.warmup_skipped;
        anyhow::ensure!(
            terminal == self.arrivals,
            "accounting leak: completed {} + dropped {} + timed_out {} + warmup_skipped {} \
             = {} != arrivals {}",
            self.completed,
            self.dropped,
            self.timed_out,
            self.warmup_skipped,
            terminal,
            self.arrivals
        );
        anyhow::ensure!(
            self.deferred_served <= self.deferred,
            "deferred_served {} > deferred {}",
            self.deferred_served,
            self.deferred
        );
        anyhow::ensure!(
            self.deferred <= self.arrivals,
            "deferred {} > arrivals {}",
            self.deferred,
            self.arrivals
        );
        anyhow::ensure!(
            self.deferred_served <= self.completed + self.warmup_skipped,
            "deferred_served {} > completed {} + warmup_skipped {}",
            self.deferred_served,
            self.completed,
            self.warmup_skipped
        );
        Ok(())
    }

    pub fn mean_ms(&self) -> f64 {
        self.e2e_ms.mean()
    }

    /// Mean latency breakdown as (preprocess, batching, dispatch, exec) ms.
    pub fn breakdown_ms(&self) -> (f64, f64, f64, f64) {
        (
            self.preprocess_ms.mean(),
            self.batching_ms.mean(),
            self.dispatch_ms.mean(),
            self.execution_ms.mean(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::millis;

    fn parts(pre: f64, bat: f64, disp: f64, exec: f64) -> LatencyParts {
        LatencyParts {
            preprocess: millis(pre),
            batching: millis(bat),
            dispatch_wait: millis(disp),
            execution: millis(exec),
        }
    }

    #[test]
    fn total_sums_parts() {
        let p = parts(1.0, 2.0, 3.0, 4.0);
        assert_eq!(to_millis(p.total()), 10.0);
    }

    #[test]
    fn throughput_from_completion_window() {
        let mut s = RunStats::new();
        // 11 completions over 1 s -> 10 intervals / 1 s = 10 qps.
        for i in 0..=10 {
            s.record(parts(0.0, 0.0, 0.0, 1.0), millis(i as f64 * 100.0), 1);
        }
        assert!((s.throughput_qps() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_window_falls_back_to_horizon() {
        // One completion: no window at all.
        let mut s = RunStats::new();
        s.record(parts(0.0, 0.0, 0.0, 1.0), millis(500.0), 1);
        assert_eq!(s.throughput_qps(), 0.0, "no horizon yet");
        s.note_horizon(millis(2000.0));
        assert!((s.throughput_qps() - 0.5).abs() < 1e-9);
        // All completions at one timestamp: zero-width window.
        let mut s = RunStats::new();
        for _ in 0..4 {
            s.record(parts(0.0, 0.0, 0.0, 1.0), millis(100.0), 4);
        }
        s.note_horizon(millis(1000.0));
        assert!((s.throughput_qps() - 4.0).abs() < 1e-9);
        // A healthy window ignores the horizon entirely.
        let mut s = RunStats::new();
        for i in 0..=10 {
            s.record(parts(0.0, 0.0, 0.0, 1.0), millis(i as f64 * 100.0), 1);
        }
        s.note_horizon(millis(60_000.0));
        assert!((s.throughput_qps() - 10.0).abs() < 1e-9);
        // note_horizon keeps the max across calls.
        let mut s = RunStats::new();
        s.note_horizon(millis(1000.0));
        s.note_horizon(millis(10.0));
        s.record(parts(0.0, 0.0, 0.0, 1.0), millis(1.0), 1);
        assert!((s.throughput_qps() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_means() {
        let mut s = RunStats::new();
        s.record(parts(2.0, 4.0, 0.0, 10.0), millis(1.0), 2);
        s.record(parts(4.0, 8.0, 0.0, 20.0), millis(2.0), 4);
        let (pre, bat, disp, exec) = s.breakdown_ms();
        assert_eq!((pre, bat, disp, exec), (3.0, 6.0, 0.0, 15.0));
        assert_eq!(s.batch_sizes.mean(), 3.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = RunStats::new();
        assert_eq!(s.throughput_qps(), 0.0);
        assert_eq!(s.p95_ms(), 0.0);
        assert_eq!(s.sla_violation_frac(10.0), 0.0);
    }

    #[test]
    fn energy_counters_default_zero_and_divide_safely() {
        let mut s = RunStats::new();
        assert_eq!(s.energy_j(), 0.0);
        assert_eq!(s.joules_per_query(), 0.0);
        assert_eq!(s.perf_per_watt(), 0.0);
        s.record(parts(0.0, 0.0, 0.0, 1.0), millis(1.0), 1);
        s.record(parts(0.0, 0.0, 0.0, 1.0), millis(2.0), 1);
        s.energy.gpu_active_j = 6.0;
        s.energy.base_j = 4.0;
        assert_eq!(s.energy_j(), 10.0);
        assert_eq!(s.joules_per_query(), 5.0);
        assert!((s.perf_per_watt() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn admission_counters_default_zero_and_served_frac() {
        let mut s = RunStats::new();
        assert_eq!((s.dropped, s.deferred, s.deferred_served), (0, 0, 0));
        assert_eq!(s.served_frac(), 1.0);
        s.record(parts(0.0, 0.0, 0.0, 1.0), millis(1.0), 1);
        s.dropped = 3;
        assert_eq!(s.served_frac(), 0.25);
    }

    #[test]
    fn availability_counts_fault_timeouts_against_demand() {
        let mut s = RunStats::new();
        assert_eq!(s.availability_frac(), 1.0);
        s.record(parts(0.0, 0.0, 0.0, 1.0), millis(1.0), 1);
        assert_eq!(s.availability_frac(), 1.0);
        s.timed_out = 2;
        s.dropped = 1;
        assert_eq!(s.availability_frac(), 0.25);
        assert_eq!(s.served_frac(), 0.5, "served_frac ignores timeouts");
    }

    #[test]
    fn audit_checks_terminal_conservation() {
        // No arrivals recorded: vacuously Ok (legacy drivers).
        let mut s = RunStats::new();
        s.completed = 5;
        assert!(s.audit().is_ok());
        // Balanced books pass.
        s.arrivals = 10;
        s.dropped = 2;
        s.timed_out = 1;
        s.warmup_skipped = 2;
        assert!(s.audit().is_ok());
        // A leaked request fails.
        s.dropped = 1;
        assert!(s.audit().is_err());
        s.dropped = 2;
        // Admission inequalities.
        s.deferred = 3;
        s.deferred_served = 4;
        assert!(s.audit().is_err(), "deferred_served > deferred");
        s.deferred_served = 3;
        assert!(s.audit().is_ok());
        s.deferred = 11;
        assert!(s.audit().is_err(), "deferred > arrivals");
        // The mixed-warmup counterexample that motivated warmup_skipped:
        // warmup=2, 4 arrivals; idx0 dropped inside warmup (uncounted),
        // idx1..3 complete but the first two completions are order-skipped.
        let mut s = RunStats::new();
        s.arrivals = 4;
        s.completed = 1;
        s.warmup_skipped = 3;
        assert!(s.audit().is_ok());
    }

    #[test]
    fn sla_violations_counted() {
        let mut s = RunStats::new();
        s.record(parts(0.0, 0.0, 0.0, 10.0), millis(1.0), 1);
        s.record(parts(0.0, 0.0, 0.0, 30.0), millis(2.0), 1);
        assert_eq!(s.sla_violation_frac(20.0), 0.5);
        assert_eq!(s.sla_violation_frac(40.0), 0.0);
    }
}
