//! `artifacts/manifest.json` — the contract between build-time Python and
//! the runtime Rust binary.
//!
//! `python/compile/aot.py` lowers every (model × batch × audio-length
//! bucket) plus every preprocessing kernel to HLO text and records each
//! artifact here with its input/output shapes and analytic FLOP counts for
//! the *lite* graph that actually executes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// One lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Registry key, e.g. `model/mobilenet/b4` or `kernel/image_pipeline/b1`.
    pub key: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Model or kernel name.
    pub name: String,
    /// Batch size this artifact was lowered at.
    pub batch: usize,
    /// Audio-length bucket in seconds (0 for vision/kernels without one).
    pub len_s: f64,
    /// DATA input shapes, row-major (each a Vec of dims) — excludes the
    /// leading weight parameters.
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes.
    pub outputs: Vec<Vec<usize>>,
    /// Binary side file holding the leading constant parameters (model
    /// weights / kernel matrices) as concatenated f32 LE, or None.
    pub weights_file: Option<String>,
    /// Shapes of the weight parameters, in HLO parameter order.
    pub weight_shapes: Vec<Vec<usize>>,
    /// Analytic forward FLOPs of the lite graph (from JAX cost analysis).
    pub flops_lite: f64,
    /// Lite-graph parameter count.
    pub params_lite: u64,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> anyhow::Result<Manifest> {
        let path = Path::new(dir).join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!("cannot read {}: {e} (run `make artifacts`)", path.display())
        })?;
        let doc = json::parse(&text)?;
        let mut entries = BTreeMap::new();
        for item in doc.req("artifacts")?.as_arr().unwrap_or(&[]) {
            let e = ArtifactEntry::from_json(item)?;
            entries.insert(e.key.clone(), e);
        }
        Ok(Manifest { dir: PathBuf::from(dir), entries })
    }

    /// Whether a manifest exists under `dir`.
    pub fn exists(dir: &str) -> bool {
        Path::new(dir).join("manifest.json").is_file()
    }

    pub fn get(&self, key: &str) -> Option<&ArtifactEntry> {
        self.entries.get(key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactEntry> {
        self.entries.values()
    }

    /// All artifacts for a given model/kernel name.
    pub fn for_name<'a>(&'a self, name: &'a str) -> impl Iterator<Item = &'a ArtifactEntry> {
        self.entries.values().filter(move |e| e.name == name)
    }

    /// Model artifact for (name, batch, len bucket), if lowered.
    pub fn model(&self, name: &str, batch: usize, len_s: f64) -> Option<&ArtifactEntry> {
        self.entries.values().find(|e| {
            e.key.starts_with("model/")
                && e.name == name
                && e.batch == batch
                && (e.len_s - len_s).abs() < 1e-6
        })
    }

    /// Largest lowered batch ≤ `batch` for a model (the runtime pads up to
    /// the nearest lowered batch; this finds the floor for splitting).
    pub fn batches_for(&self, name: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .values()
            .filter(|e| e.name == name && e.key.starts_with("model/"))
            .map(|e| e.batch)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

impl ArtifactEntry {
    fn from_json(v: &Json) -> anyhow::Result<ArtifactEntry> {
        let shapes = |key: &str| -> anyhow::Result<Vec<Vec<usize>>> {
            Ok(v.req(key)?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect::<Vec<_>>()
                })
                .collect())
        };
        Ok(ArtifactEntry {
            key: v.req("key")?.as_str().unwrap_or_default().to_string(),
            file: v.req("file")?.as_str().unwrap_or_default().to_string(),
            name: v.req("name")?.as_str().unwrap_or_default().to_string(),
            batch: v.req("batch")?.as_usize().unwrap_or(1),
            len_s: v.get("len_s").and_then(Json::as_f64).unwrap_or(0.0),
            inputs: shapes("inputs")?,
            outputs: shapes("outputs")?,
            weights_file: v
                .get("weights_file")
                .and_then(Json::as_str)
                .map(str::to_string),
            weight_shapes: if v.get("weight_shapes").is_some() {
                shapes("weight_shapes")?
            } else {
                Vec::new()
            },
            flops_lite: v.get("flops_lite").and_then(Json::as_f64).unwrap_or(0.0),
            params_lite: v.get("params_lite").and_then(Json::as_u64).unwrap_or(0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> &'static str {
        r#"{
          "version": 1,
          "artifacts": [
            {"key": "model/mobilenet/b1", "file": "mobilenet_b1.hlo.txt",
             "name": "mobilenet", "batch": 1, "len_s": 0,
             "inputs": [[1, 64, 64, 3]], "outputs": [[1, 1000]],
             "flops_lite": 1e7, "params_lite": 250000},
            {"key": "model/mobilenet/b4", "file": "mobilenet_b4.hlo.txt",
             "name": "mobilenet", "batch": 4, "len_s": 0,
             "inputs": [[4, 64, 64, 3]], "outputs": [[4, 1000]],
             "flops_lite": 4e7, "params_lite": 250000},
            {"key": "kernel/image_pipeline/b1", "file": "k_img_b1.hlo.txt",
             "name": "image_pipeline", "batch": 1, "len_s": 0,
             "inputs": [[1, 96, 96, 3]], "outputs": [[1, 64, 64, 3]],
             "flops_lite": 1e6, "params_lite": 0}
          ]
        }"#
    }

    #[test]
    fn parse_and_query() {
        let dir = std::env::temp_dir().join("preba_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        let m = Manifest::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.len(), 3);
        let e = m.model("mobilenet", 4, 0.0).unwrap();
        assert_eq!(e.inputs[0], vec![4, 64, 64, 3]);
        assert_eq!(m.batches_for("mobilenet"), vec![1, 4]);
        assert!(m.get("kernel/image_pipeline/b1").is_some());
        assert!(m.model("mobilenet", 2, 0.0).is_none());
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load("/definitely/not/here").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
