//! Model registry: the six paper workloads.
//!
//! Each [`ModelSpec`] carries two views of a model:
//!
//! * **full-scale performance numbers** (`flops_full`, `params_full`,
//!   `plateau_qps_per_gpc`, knee targets) describing the *paper's* models
//!   (MobileNetV3-Small, SqueezeNet 1.1, Swin-T, NeMo Conformer
//!   small/default, CitriNet) on the A100 — these drive the calibrated MIG
//!   service-time model (`mig::ServiceModel`) used by the figure
//!   simulations; and
//! * **lite execution artifacts** — the JAX re-implementations lowered by
//!   `python/compile/aot.py` and really executed on the PJRT CPU client by
//!   the real driver (shape-faithful, reduced width/depth so a single CPU
//!   core can run them).
//!
//! The split is documented in DESIGN.md §4 (substitution table): batching
//! and scheduling behaviour depends on the *shape* of the service-time
//! curve, which is pinned to the paper's measured knees; numerics are
//! validated by executing the lite HLO for real.

pub mod calib;
pub mod manifest;

pub use calib::{batch_bucket, CurvePoint, CurveView, N_BUCKETS};
pub use manifest::{ArtifactEntry, Manifest};

/// The six paper workloads (§5 "Benchmarks").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelId {
    MobileNet,
    SqueezeNet,
    SwinTransformer,
    ConformerSmall,
    ConformerDefault,
    CitriNet,
}

impl ModelId {
    pub const ALL: [ModelId; 6] = [
        ModelId::MobileNet,
        ModelId::SqueezeNet,
        ModelId::SwinTransformer,
        ModelId::ConformerSmall,
        ModelId::ConformerDefault,
        ModelId::CitriNet,
    ];

    pub const VISION: [ModelId; 3] =
        [ModelId::MobileNet, ModelId::SqueezeNet, ModelId::SwinTransformer];

    pub const AUDIO: [ModelId; 3] =
        [ModelId::ConformerSmall, ModelId::ConformerDefault, ModelId::CitriNet];

    pub fn name(&self) -> &'static str {
        match self {
            ModelId::MobileNet => "mobilenet",
            ModelId::SqueezeNet => "squeezenet",
            ModelId::SwinTransformer => "swin",
            ModelId::ConformerSmall => "conformer_small",
            ModelId::ConformerDefault => "conformer_default",
            ModelId::CitriNet => "citrinet",
        }
    }

    /// Paper display name.
    pub fn display(&self) -> &'static str {
        match self {
            ModelId::MobileNet => "MobileNet",
            ModelId::SqueezeNet => "SqueezeNet",
            ModelId::SwinTransformer => "Swin-Transformer",
            ModelId::ConformerSmall => "Conformer(small)",
            ModelId::ConformerDefault => "Conformer(default)",
            ModelId::CitriNet => "CitriNet",
        }
    }

    pub fn parse(s: &str) -> Option<ModelId> {
        ModelId::ALL.iter().copied().find(|m| m.name() == s)
    }

    pub fn kind(&self) -> ModelKind {
        match self {
            ModelId::MobileNet | ModelId::SqueezeNet | ModelId::SwinTransformer => {
                ModelKind::Vision
            }
            _ => ModelKind::Audio,
        }
    }

    pub fn spec(&self) -> &'static ModelSpec {
        calib::spec(*self)
    }
}

impl std::fmt::Display for ModelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.display())
    }
}

/// Input modality (paper §2.3: image vs audio preprocessing pipelines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Vision,
    Audio,
}

/// Full static description of one workload. See module docs for the
/// full-scale vs lite split.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub id: ModelId,
    pub kind: ModelKind,

    // ---- full-scale (paper model) numbers, drive the service model ----
    /// Parameter count of the paper's model.
    pub params_full: u64,
    /// Forward-pass FLOPs for ONE sample. For audio this is per second of
    /// input audio (multiply by length); vision inputs are fixed 224x224x3.
    pub flops_full: f64,
    /// Measured-calibrated saturated throughput of a 1-GPC (1g.5gb) slice,
    /// queries/s, for a 2.5 s audio input where applicable. This pins the
    /// service-model plateau (see `mig::ServiceModel`).
    pub plateau_qps_per_gpc: f64,
    /// Paper-measured Batch_knee on a 1g.5gb slice (vision only; audio
    /// knees derive from Time_knee — paper Fig 15). Fig 6: 16 / 4 / 2.
    pub knee_1g: Option<usize>,
    /// Paper-measured Batch_knee on the unpartitioned 7g.40gb GPU
    /// (vision only). Fig 6: 128 / 32 / 16.
    pub knee_7g: Option<usize>,
    /// Tail latency at the knee (`Time_knee`), seconds. Audio: ~0.035
    /// regardless of length (paper Fig 15). Vision: derived from knee and
    /// plateau, stored for reporting.
    pub time_knee_s: f64,

    // ---- preprocessing (paper §3.3 / Fig 8) ----
    /// CPU time to preprocess ONE input on ONE core, seconds (OpenCV /
    /// Librosa path). Audio: per request at 2.5 s input; scales with
    /// length. Calibrated so Fig 8's cores-required reproduce (CitriNet:
    /// 393 cores).
    pub cpu_preproc_s: f64,
    /// Raw input bytes arriving at the server (JPEG / PCM), per request at
    /// the reference input size.
    pub raw_input_bytes: u64,
    /// Preprocessed tensor bytes handed to the GPU per request.
    pub tensor_bytes: u64,
}

impl ModelSpec {
    /// Forward FLOPs for a batch of `b` inputs of `len_s` seconds (audio)
    /// or fixed-size images (vision; `len_s` ignored).
    pub fn flops(&self, b: usize, len_s: f64) -> f64 {
        match self.kind {
            ModelKind::Vision => self.flops_full * b as f64,
            ModelKind::Audio => self.flops_full * len_s * b as f64,
        }
    }

    /// Per-request preprocessing CPU seconds for an input of `len_s`.
    pub fn cpu_preproc_secs(&self, len_s: f64) -> f64 {
        match self.kind {
            ModelKind::Vision => self.cpu_preproc_s,
            // Audio preprocessing cost scales with the number of samples.
            ModelKind::Audio => self.cpu_preproc_s * (len_s / 2.5),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_complete() {
        for m in ModelId::ALL {
            let s = m.spec();
            assert_eq!(s.id, m);
            assert!(s.flops_full > 0.0);
            assert!(s.plateau_qps_per_gpc > 0.0);
            assert!(s.cpu_preproc_s > 0.0);
        }
    }

    #[test]
    fn vision_have_paper_knees() {
        assert_eq!(ModelId::MobileNet.spec().knee_1g, Some(16));
        assert_eq!(ModelId::SqueezeNet.spec().knee_1g, Some(4));
        assert_eq!(ModelId::SwinTransformer.spec().knee_1g, Some(2));
        assert_eq!(ModelId::MobileNet.spec().knee_7g, Some(128));
        assert_eq!(ModelId::SqueezeNet.spec().knee_7g, Some(32));
        assert_eq!(ModelId::SwinTransformer.spec().knee_7g, Some(16));
    }

    #[test]
    fn audio_time_knee_is_35ms() {
        for m in ModelId::AUDIO {
            assert!((m.spec().time_knee_s - 0.035).abs() < 1e-9, "{m}");
        }
    }

    #[test]
    fn parse_roundtrip() {
        for m in ModelId::ALL {
            assert_eq!(ModelId::parse(m.name()), Some(m));
        }
        assert_eq!(ModelId::parse("nope"), None);
    }

    #[test]
    fn audio_flops_scale_with_length() {
        let s = ModelId::CitriNet.spec();
        assert!((s.flops(2, 5.0) / s.flops(1, 2.5) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn kinds() {
        for m in ModelId::VISION {
            assert_eq!(m.kind(), ModelKind::Vision);
        }
        for m in ModelId::AUDIO {
            assert_eq!(m.kind(), ModelKind::Audio);
        }
    }
}
