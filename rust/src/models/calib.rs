//! Calibration table: the full-scale numbers that pin the MIG service
//! model to the paper's measurements.
//!
//! Provenance per column:
//! * `params_full` / `flops_full` — public numbers for the exact model
//!   variants the paper names (TorchHub / NVIDIA NeMo model cards):
//!   MobileNetV3-Small (2.5 M params, ~0.11 GFLOPs @224), SqueezeNet 1.1
//!   (1.24 M, ~0.7 GFLOPs), Swin-T (28 M, ~9 GFLOPs), Conformer-CTC small
//!   (13 M) / large-ish "default" (121 M), CitriNet-1024 (142 M). Audio
//!   FLOPs are per second of 16 kHz input.
//! * `knee_1g` / `knee_7g` — paper §3.2: Batch_knee 16/4/2 (1g.5gb) and
//!   128/32/16 (7g.40gb) for MobileNet/SqueezeNet/Swin.
//! * `time_knee_s` — paper Fig 15: ~35 ms for audio models regardless of
//!   input length; vision values derived (knee·t_samp·10/9).
//! * `plateau_qps_per_gpc` — calibrated so that (a) per-slice latency at
//!   the knee lands in the few-to-tens-of-ms band the paper reports and
//!   (b) Fig 8's preprocessing cores-required reproduce (CitriNet 393).
//! * `cpu_preproc_s` — calibrated against Fig 8: cores_required =
//!   ideal_aggregate_qps(1g.5gb(7x)) × cpu_preproc_s. CitriNet:
//!   7 × 250 QPS × 0.2246 s ≈ 393 cores (the paper's headline number).
//!   Vision ≈ 12 ms/image is in line with OpenCV JPEG decode+resize at
//!   224², audio ≈ 225 ms at 2.5 s with Librosa's mel pipeline.

use super::{ModelId, ModelKind, ModelSpec};

/// 1 GFLOP.
const G: f64 = 1e9;
/// 1 million.
const M: u64 = 1_000_000;

static MOBILENET: ModelSpec = ModelSpec {
    id: ModelId::MobileNet,
    kind: ModelKind::Vision,
    params_full: 2_500_000,
    flops_full: 0.112 * G,
    plateau_qps_per_gpc: 2500.0,
    knee_1g: Some(16),
    knee_7g: Some(128),
    // (10/9) * knee * t_samp = (10/9) * 16 / 2500
    time_knee_s: 0.00711,
    cpu_preproc_s: 0.012,
    raw_input_bytes: 110 * 1024,      // ~110 KB JPEG
    tensor_bytes: 224 * 224 * 3 * 4,  // f32 CHW tensor
};

static SQUEEZENET: ModelSpec = ModelSpec {
    id: ModelId::SqueezeNet,
    kind: ModelKind::Vision,
    params_full: 1_240_000,
    flops_full: 0.70 * G,
    plateau_qps_per_gpc: 1200.0,
    knee_1g: Some(4),
    knee_7g: Some(32),
    time_knee_s: 0.0037,
    cpu_preproc_s: 0.012,
    raw_input_bytes: 110 * 1024,
    tensor_bytes: 224 * 224 * 3 * 4,
};

static SWIN: ModelSpec = ModelSpec {
    id: ModelId::SwinTransformer,
    kind: ModelKind::Vision,
    params_full: 28 * M,
    flops_full: 9.0 * G,
    plateau_qps_per_gpc: 220.0,
    knee_1g: Some(2),
    knee_7g: Some(16),
    time_knee_s: 0.0101,
    // Swin's eval transform (bicubic resize 256 -> center-crop 224 with
    // antialiasing) is markedly heavier than the small CNNs' bilinear
    // pipeline; calibrated so Fig 8's average drop lands near the
    // paper's 75.6%.
    cpu_preproc_s: 0.060,
    raw_input_bytes: 110 * 1024,
    tensor_bytes: 224 * 224 * 3 * 4,
};

static CONFORMER_SMALL: ModelSpec = ModelSpec {
    id: ModelId::ConformerSmall,
    kind: ModelKind::Audio,
    params_full: 13 * M,
    flops_full: 2.6 * G, // per second of audio
    plateau_qps_per_gpc: 180.0,
    knee_1g: None,
    knee_7g: None,
    time_knee_s: 0.035,
    cpu_preproc_s: 0.200, // at 2.5 s input
    raw_input_bytes: (2.5 * 16000.0 * 2.0) as u64, // 16 kHz s16 PCM, 2.5 s
    tensor_bytes: 80 * 251 * 4,                    // 80 mel bins x ~100 fr/s
};

static CONFORMER_DEFAULT: ModelSpec = ModelSpec {
    id: ModelId::ConformerDefault,
    kind: ModelKind::Audio,
    params_full: 121 * M,
    flops_full: 21.0 * G,
    plateau_qps_per_gpc: 60.0,
    knee_1g: None,
    knee_7g: None,
    time_knee_s: 0.035,
    cpu_preproc_s: 0.200,
    raw_input_bytes: (2.5 * 16000.0 * 2.0) as u64,
    tensor_bytes: 80 * 251 * 4,
};

static CITRINET: ModelSpec = ModelSpec {
    id: ModelId::CitriNet,
    kind: ModelKind::Audio,
    params_full: 142 * M,
    flops_full: 10.5 * G,
    plateau_qps_per_gpc: 250.0,
    knee_1g: None,
    knee_7g: None,
    time_knee_s: 0.035,
    // Pinned to the paper's 393-core number:
    // 7 slices x 250 QPS x 0.2246 s = 393.0 cores.
    cpu_preproc_s: 0.2246,
    raw_input_bytes: (2.5 * 16000.0 * 2.0) as u64,
    tensor_bytes: 80 * 251 * 4,
};

/// Static spec for a model id.
pub fn spec(id: ModelId) -> &'static ModelSpec {
    match id {
        ModelId::MobileNet => &MOBILENET,
        ModelId::SqueezeNet => &SQUEEZENET,
        ModelId::SwinTransformer => &SWIN,
        ModelId::ConformerSmall => &CONFORMER_SMALL,
        ModelId::ConformerDefault => &CONFORMER_DEFAULT,
        ModelId::CitriNet => &CITRINET,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn citrinet_cores_required_is_393() {
        let s = spec(ModelId::CitriNet);
        let ideal_qps = 7.0 * s.plateau_qps_per_gpc;
        let cores = ideal_qps * s.cpu_preproc_s;
        assert!((cores - 393.0).abs() < 1.0, "cores={cores}");
    }

    #[test]
    fn knee_ratio_7g_over_1g_is_8x() {
        for m in ModelId::VISION {
            let s = spec(m);
            assert_eq!(s.knee_7g.unwrap() / s.knee_1g.unwrap(), 8, "{m}");
        }
    }

    #[test]
    fn vision_time_knee_consistent() {
        // time_knee = (10/9) * knee / plateau (see mig::ServiceModel docs)
        for m in ModelId::VISION {
            let s = spec(m);
            let expect = (10.0 / 9.0) * s.knee_1g.unwrap() as f64 / s.plateau_qps_per_gpc;
            assert!((s.time_knee_s - expect).abs() / expect < 0.01, "{m}: {expect}");
        }
    }
}
