//! Calibration table: the full-scale numbers that pin the MIG service
//! model to the paper's measurements.
//!
//! Provenance per column:
//! * `params_full` / `flops_full` — public numbers for the exact model
//!   variants the paper names (TorchHub / NVIDIA NeMo model cards):
//!   MobileNetV3-Small (2.5 M params, ~0.11 GFLOPs @224), SqueezeNet 1.1
//!   (1.24 M, ~0.7 GFLOPs), Swin-T (28 M, ~9 GFLOPs), Conformer-CTC small
//!   (13 M) / large-ish "default" (121 M), CitriNet-1024 (142 M). Audio
//!   FLOPs are per second of 16 kHz input.
//! * `knee_1g` / `knee_7g` — paper §3.2: Batch_knee 16/4/2 (1g.5gb) and
//!   128/32/16 (7g.40gb) for MobileNet/SqueezeNet/Swin.
//! * `time_knee_s` — paper Fig 15: ~35 ms for audio models regardless of
//!   input length; vision values derived (knee·t_samp·10/9).
//! * `plateau_qps_per_gpc` — calibrated so that (a) per-slice latency at
//!   the knee lands in the few-to-tens-of-ms band the paper reports and
//!   (b) Fig 8's preprocessing cores-required reproduce (CitriNet 393).
//! * `cpu_preproc_s` — calibrated against Fig 8: cores_required =
//!   ideal_aggregate_qps(1g.5gb(7x)) × cpu_preproc_s. CitriNet:
//!   7 × 250 QPS × 0.2246 s ≈ 393 cores (the paper's headline number).
//!   Vision ≈ 12 ms/image is in line with OpenCV JPEG decode+resize at
//!   224², audio ≈ 225 ms at 2.5 s with Librosa's mel pipeline.

use super::{ModelId, ModelKind, ModelSpec};

/// 1 GFLOP.
const G: f64 = 1e9;
/// 1 million.
const M: u64 = 1_000_000;

static MOBILENET: ModelSpec = ModelSpec {
    id: ModelId::MobileNet,
    kind: ModelKind::Vision,
    params_full: 2_500_000,
    flops_full: 0.112 * G,
    plateau_qps_per_gpc: 2500.0,
    knee_1g: Some(16),
    knee_7g: Some(128),
    // (10/9) * knee * t_samp = (10/9) * 16 / 2500
    time_knee_s: 0.00711,
    cpu_preproc_s: 0.012,
    raw_input_bytes: 110 * 1024,      // ~110 KB JPEG
    tensor_bytes: 224 * 224 * 3 * 4,  // f32 CHW tensor
};

static SQUEEZENET: ModelSpec = ModelSpec {
    id: ModelId::SqueezeNet,
    kind: ModelKind::Vision,
    params_full: 1_240_000,
    flops_full: 0.70 * G,
    plateau_qps_per_gpc: 1200.0,
    knee_1g: Some(4),
    knee_7g: Some(32),
    time_knee_s: 0.0037,
    cpu_preproc_s: 0.012,
    raw_input_bytes: 110 * 1024,
    tensor_bytes: 224 * 224 * 3 * 4,
};

static SWIN: ModelSpec = ModelSpec {
    id: ModelId::SwinTransformer,
    kind: ModelKind::Vision,
    params_full: 28 * M,
    flops_full: 9.0 * G,
    plateau_qps_per_gpc: 220.0,
    knee_1g: Some(2),
    knee_7g: Some(16),
    time_knee_s: 0.0101,
    // Swin's eval transform (bicubic resize 256 -> center-crop 224 with
    // antialiasing) is markedly heavier than the small CNNs' bilinear
    // pipeline; calibrated so Fig 8's average drop lands near the
    // paper's 75.6%.
    cpu_preproc_s: 0.060,
    raw_input_bytes: 110 * 1024,
    tensor_bytes: 224 * 224 * 3 * 4,
};

static CONFORMER_SMALL: ModelSpec = ModelSpec {
    id: ModelId::ConformerSmall,
    kind: ModelKind::Audio,
    params_full: 13 * M,
    flops_full: 2.6 * G, // per second of audio
    plateau_qps_per_gpc: 180.0,
    knee_1g: None,
    knee_7g: None,
    time_knee_s: 0.035,
    cpu_preproc_s: 0.200, // at 2.5 s input
    raw_input_bytes: (2.5 * 16000.0 * 2.0) as u64, // 16 kHz s16 PCM, 2.5 s
    tensor_bytes: 80 * 251 * 4,                    // 80 mel bins x ~100 fr/s
};

static CONFORMER_DEFAULT: ModelSpec = ModelSpec {
    id: ModelId::ConformerDefault,
    kind: ModelKind::Audio,
    params_full: 121 * M,
    flops_full: 21.0 * G,
    plateau_qps_per_gpc: 60.0,
    knee_1g: None,
    knee_7g: None,
    time_knee_s: 0.035,
    cpu_preproc_s: 0.200,
    raw_input_bytes: (2.5 * 16000.0 * 2.0) as u64,
    tensor_bytes: 80 * 251 * 4,
};

static CITRINET: ModelSpec = ModelSpec {
    id: ModelId::CitriNet,
    kind: ModelKind::Audio,
    params_full: 142 * M,
    flops_full: 10.5 * G,
    plateau_qps_per_gpc: 250.0,
    knee_1g: None,
    knee_7g: None,
    time_knee_s: 0.035,
    // Pinned to the paper's 393-core number:
    // 7 slices x 250 QPS x 0.2246 s = 393.0 cores.
    cpu_preproc_s: 0.2246,
    raw_input_bytes: (2.5 * 16000.0 * 2.0) as u64,
    tensor_bytes: 80 * 251 * 4,
};

// ---------------------------------------------------------------------------
// Per-(model, MIG profile, batch-bucket) performance/energy curves.
//
// MIGPerf (arXiv 2301.00407) measures that throughput, tail latency and
// J/query are NOT workload-independent across MIG geometries: memory-bound
// models on small slices lose disproportionate latency at large batches
// (L2/HBM capacity pressure), lightly-batched work draws well below the
// per-GPC active-power plateau, and co-located slices contend through the
// shared uncore (HBM controllers + L2) even though SMs are partitioned.
//
// We encode those findings as multiplicative corrections on top of the
// affine `mig::ServiceModel`: a latency multiplier and an active-power
// multiplier per (model, profile, batch-size bucket), plus a per-profile
// contention coefficient applied per busy *neighbor* slice at dispatch.
// The defaults below are calibrated to the MIGPerf trend lines (not to a
// single figure): the correction grows with the model's memory intensity,
// with batch size, and with slice smallness, and vanishes on the
// unpartitioned 7g geometry where there are no neighbors and the affine
// model was fit directly.
// ---------------------------------------------------------------------------

/// Number of batch-size buckets in a curve row.
pub const N_BUCKETS: usize = 4;

/// Bucket a batch size: 0 (<=2), 1 (<=8), 2 (<=32), 3 (larger). The
/// boundaries straddle the paper's 1g/7g knees (2..128) so every model's
/// operating range spans several buckets.
pub fn batch_bucket(batch: usize) -> usize {
    match batch {
        0..=2 => 0,
        3..=8 => 1,
        9..=32 => 2,
        _ => 3,
    }
}

/// Latency/active-power multiplier for one (model, profile, bucket) cell.
/// `1.0` means "the affine service model / flat per-GPC watts are exact".
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    pub lat_mult: f64,
    pub pow_mult: f64,
}

/// Relative memory-bandwidth intensity of a model in `[0, 1]`, the knob
/// that determines how strongly it deviates from the flat model on small
/// slices (MIGPerf: memory-bound models suffer most under partitioning).
fn memory_intensity(id: ModelId) -> f64 {
    match id {
        ModelId::MobileNet => 0.25,
        ModelId::SqueezeNet => 0.30,
        ModelId::SwinTransformer => 0.55,
        ModelId::ConformerSmall => 0.45,
        ModelId::ConformerDefault => 0.60,
        ModelId::CitriNet => 0.50,
    }
}

/// MIGPerf-calibrated default curve row for `(model, gpcs)`.
///
/// Shape: `lat_mult` rises with batch bucket and slice smallness (capacity
/// pressure), up to +35% for a fully memory-bound model at the largest
/// bucket on 1g; `pow_mult` starts below 1.0 at tiny batches (the slice
/// never reaches its active-power plateau) and crosses above 1.0 only for
/// memory-bound large batches on small slices. On 7g both collapse toward
/// the affine fit.
pub fn migperf_curve(model: ModelId, gpcs: usize) -> [CurvePoint; N_BUCKETS] {
    let mi = memory_intensity(model);
    // Slice "smallness": 1g -> 1.0, 7g -> 0.0.
    let s = 1.0 - (gpcs.clamp(1, 7) - 1) as f64 / 6.0;
    let mut row = [CurvePoint { lat_mult: 1.0, pow_mult: 1.0 }; N_BUCKETS];
    for (b, pt) in row.iter_mut().enumerate() {
        let fb = b as f64 / (N_BUCKETS - 1) as f64;
        pt.lat_mult = 1.0 + 0.35 * mi * s * fb;
        pt.pow_mult = 0.88 + 0.12 * fb + 0.18 * mi * s * fb;
    }
    row
}

/// MIGPerf-calibrated uncore-contention coefficient for a profile:
/// fractional execution-time/power inflation per busy *neighbor* slice on
/// the same GPU. Small slices see the largest per-neighbor penalty (more
/// neighbors AND less private L2); the unpartitioned 7g has none.
pub fn migperf_contention(gpcs: usize) -> f64 {
    match gpcs {
        0 | 1 => 0.055,
        2 => 0.040,
        3 => 0.028,
        4 => 0.018,
        5 | 6 => 0.010,
        _ => 0.0,
    }
}

/// A curve row resolved for one tenant: per-bucket latency/power
/// multipliers plus the contention coefficient of its profile. This is the
/// value the dispatch paths hold — `CurvesConfig::view` resolves it once
/// per (model, geometry) so the hot path does two array reads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurveView {
    pub lat: [f64; N_BUCKETS],
    pub pow: [f64; N_BUCKETS],
    pub contention: f64,
}

impl CurveView {
    /// The identity view: multipliers 1.0 everywhere, no contention.
    /// Dispatching with it is bit-identical to the flat model.
    pub const NEUTRAL: CurveView =
        CurveView { lat: [1.0; N_BUCKETS], pow: [1.0; N_BUCKETS], contention: 0.0 };

    pub fn lat_mult(&self, batch: usize) -> f64 {
        self.lat[batch_bucket(batch)]
    }

    pub fn pow_mult(&self, batch: usize) -> f64 {
        self.pow[batch_bucket(batch)]
    }

    /// Interference penalty with `busy_neighbors` of the GPU's other
    /// slices still executing at dispatch: `1 + contention * k`.
    pub fn penalty(&self, busy_neighbors: usize) -> f64 {
        1.0 + self.contention * busy_neighbors as f64
    }

    pub fn is_neutral(&self) -> bool {
        *self == CurveView::NEUTRAL
    }

    /// Aggregate service-time scale for the *planner*: the latency
    /// multiplier at a representative batch plus the contention penalty at
    /// an assumed neighbor count. Monotone in both arguments.
    pub fn service_scale(&self, batch: usize, busy_neighbors: usize) -> f64 {
        self.lat_mult(batch) * self.penalty(busy_neighbors)
    }
}

/// Static spec for a model id.
pub fn spec(id: ModelId) -> &'static ModelSpec {
    match id {
        ModelId::MobileNet => &MOBILENET,
        ModelId::SqueezeNet => &SQUEEZENET,
        ModelId::SwinTransformer => &SWIN,
        ModelId::ConformerSmall => &CONFORMER_SMALL,
        ModelId::ConformerDefault => &CONFORMER_DEFAULT,
        ModelId::CitriNet => &CITRINET,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn citrinet_cores_required_is_393() {
        let s = spec(ModelId::CitriNet);
        let ideal_qps = 7.0 * s.plateau_qps_per_gpc;
        let cores = ideal_qps * s.cpu_preproc_s;
        assert!((cores - 393.0).abs() < 1.0, "cores={cores}");
    }

    #[test]
    fn knee_ratio_7g_over_1g_is_8x() {
        for m in ModelId::VISION {
            let s = spec(m);
            assert_eq!(s.knee_7g.unwrap() / s.knee_1g.unwrap(), 8, "{m}");
        }
    }

    #[test]
    fn curve_rows_are_sane_and_monotone_in_batch() {
        for m in ModelId::ALL {
            for gpcs in [1usize, 2, 3, 4, 7] {
                let row = migperf_curve(m, gpcs);
                for w in row.windows(2) {
                    assert!(w[1].lat_mult >= w[0].lat_mult, "{m} {gpcs}g lat not monotone");
                    assert!(w[1].pow_mult >= w[0].pow_mult, "{m} {gpcs}g pow not monotone");
                }
                for pt in row {
                    assert!(pt.lat_mult >= 1.0 && pt.lat_mult <= 1.40, "{m} {gpcs}g");
                    assert!(pt.pow_mult >= 0.80 && pt.pow_mult <= 1.25, "{m} {gpcs}g");
                }
            }
            // The unpartitioned GPU is where the affine model was fit:
            // latency corrections vanish there.
            for pt in migperf_curve(m, 7) {
                assert!((pt.lat_mult - 1.0).abs() < 1e-12, "{m} 7g");
            }
        }
    }

    #[test]
    fn contention_shrinks_with_slice_size() {
        let cs: Vec<f64> = [1, 2, 3, 4, 7].iter().map(|&g| migperf_contention(g)).collect();
        for w in cs.windows(2) {
            assert!(w[1] <= w[0], "contention must shrink with gpcs: {cs:?}");
        }
        assert_eq!(migperf_contention(7), 0.0);
    }

    #[test]
    fn neutral_view_is_exactly_identity() {
        let v = CurveView::NEUTRAL;
        for b in [0usize, 1, 2, 8, 9, 32, 33, 4096] {
            assert_eq!(v.lat_mult(b).to_bits(), 1.0f64.to_bits());
            assert_eq!(v.pow_mult(b).to_bits(), 1.0f64.to_bits());
        }
        for k in 0..8 {
            assert_eq!(v.penalty(k).to_bits(), 1.0f64.to_bits());
        }
        assert!(v.is_neutral());
    }

    #[test]
    fn bucket_boundaries() {
        assert_eq!(batch_bucket(1), 0);
        assert_eq!(batch_bucket(2), 0);
        assert_eq!(batch_bucket(3), 1);
        assert_eq!(batch_bucket(8), 1);
        assert_eq!(batch_bucket(9), 2);
        assert_eq!(batch_bucket(32), 2);
        assert_eq!(batch_bucket(33), 3);
        assert_eq!(batch_bucket(128), 3);
    }

    #[test]
    fn vision_time_knee_consistent() {
        // time_knee = (10/9) * knee / plateau (see mig::ServiceModel docs)
        for m in ModelId::VISION {
            let s = spec(m);
            let expect = (10.0 / 9.0) * s.knee_1g.unwrap() as f64 / s.plateau_qps_per_gpc;
            assert!((s.time_knee_s - expect).abs() / expect < 0.01, "{m}: {expect}");
        }
    }
}
