//! Command-line argument parsing (in lieu of `clap`, absent offline).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name). The first non-dashed
    /// token becomes the subcommand; later non-dashed tokens are
    /// positionals. `bool_flags` lists options that take no value.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("option --{stripped} expects a value"))?;
                    out.options.insert(stripped.to_string(), v.clone());
                }
            } else if out.command.is_none() {
                out.command = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env(bool_flags: &[&str]) -> anyhow::Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, bool_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Error if options outside `known` were passed (catches typos).
    pub fn check_known(&self, known: &[&str]) -> anyhow::Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                anyhow::bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a =
            Args::parse(&argv("serve --model mobilenet --qps=100 --verbose pos1"), &["verbose"])
                .unwrap();
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.opt("model"), Some("mobilenet"));
        assert_eq!(a.opt("qps"), Some("100"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(&argv("x --n 42 --rate 2.5"), &[]).unwrap();
        assert_eq!(a.opt_u64("n", 0).unwrap(), 42);
        assert_eq!(a.opt_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.opt_u64("missing", 7).unwrap(), 7);
        assert!(a.opt_u64("rate", 0).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv("x --model"), &[]).is_err());
    }

    #[test]
    fn unknown_option_check() {
        let a = Args::parse(&argv("x --good 1 --bad 2"), &[]).unwrap();
        assert!(a.check_known(&["good"]).is_err());
        assert!(a.check_known(&["good", "bad"]).is_ok());
    }
}
