//! Time abstraction shared by the DES driver and the real-PJRT driver.
//!
//! All coordinator logic (batching deadlines, `Time_queue` accounting,
//! SLA tracking) is written against nanosecond timestamps ([`Nanos`]) from
//! a [`Clock`], so the same code runs under the virtual clock of the
//! discrete-event simulator and the monotonic wall clock of the real
//! serving driver.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Nanoseconds since an arbitrary epoch (simulation start / process start).
pub type Nanos = u64;

/// Convert seconds (f64) to [`Nanos`], saturating.
pub fn secs(s: f64) -> Nanos {
    (s * 1e9).round().max(0.0) as Nanos
}

/// Convert milliseconds to [`Nanos`].
pub fn millis(ms: f64) -> Nanos {
    secs(ms * 1e-3)
}

/// Convert microseconds to [`Nanos`].
pub fn micros(us: f64) -> Nanos {
    secs(us * 1e-6)
}

/// [`Nanos`] to seconds.
pub fn to_secs(n: Nanos) -> f64 {
    n as f64 * 1e-9
}

/// [`Nanos`] to milliseconds.
pub fn to_millis(n: Nanos) -> f64 {
    n as f64 * 1e-6
}

/// A source of "now". Implementations must be monotonic.
pub trait Clock: Send + Sync {
    fn now(&self) -> Nanos;
}

/// Wall-clock time from a process-start epoch.
pub struct RealClock {
    epoch: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock { epoch: Instant::now() }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> Nanos {
        self.epoch.elapsed().as_nanos() as Nanos
    }
}

/// Manually-advanced clock used by the discrete-event simulator. Shared
/// (atomic) so metric recorders can read it from anywhere.
pub struct VirtualClock {
    now: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now: AtomicU64::new(0) }
    }

    /// Advance to `t`. Panics if time would move backwards (a DES bug).
    pub fn advance_to(&self, t: Nanos) {
        let prev = self.now.swap(t, Ordering::SeqCst);
        assert!(prev <= t, "virtual time moved backwards: {prev} -> {t}");
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Nanos {
        self.now.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(secs(1.5), 1_500_000_000);
        assert_eq!(millis(35.0), 35_000_000);
        assert_eq!(micros(2.0), 2_000);
        assert!((to_secs(secs(3.25)) - 3.25).abs() < 1e-12);
        assert!((to_millis(millis(7.5)) - 7.5).abs() < 1e-9);
    }

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        c.advance_to(10);
        assert_eq!(c.now(), 10);
        c.advance_to(10); // equal is fine
    }

    #[test]
    #[should_panic(expected = "moved backwards")]
    fn virtual_clock_rejects_backwards() {
        let c = VirtualClock::new();
        c.advance_to(10);
        c.advance_to(5);
    }
}
