//! `preba` — the PREBA MIG inference server CLI (L3 leader entrypoint).
//!
//! Subcommands:
//! * `serve`      — run the real-PJRT serving pipeline on AOT artifacts.
//! * `simulate`   — one DES run with explicit knobs (model/mig/preproc/...).
//! * `profile`    — offline Batch_knee profiling for a model+MIG config.
//! * `energy`     — integrated energy & cost: baseline vs PREBA per model.
//! * `experiment` — regenerate a paper figure/table (`all` for everything).
//! * `list`       — enumerate models, MIG configs and experiments.

use preba::cli::Args;
use preba::config::PrebaConfig;
use preba::mig::MigConfig;
use preba::models::ModelId;
use preba::server::{real_driver, sim_driver, PolicyKind, PreprocMode, SimConfig};
use preba::util::table::{num, Table};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: preba <serve|simulate|profile|plan|reconfig|cluster|energy|interference|report|experiment|list> [options]\n\
     \n\
     serve      --model M [--preproc host|dpu] [--rate QPS] [--requests N] [--artifacts DIR]\n\
     simulate   --model M [--mig 1g|2g|7g] [--preproc ideal|cpu|dpu] [--policy static|dynamic]\n\
                [--servers N] [--rate QPS] [--requests N] [--seed S]\n\
                [--profile constant|diurnal|bursty] [--sla MS] [--reconfig]\n\
                (--reconfig: online MIG repartitioning — a controller watches\n\
                windowed arrival rates and repartitions with drain + outage)\n\
     profile    --model M [--mig 1g|2g|7g] [--len SECONDS]\n\
     plan       --model M [--sla MS] [--len SECONDS]   (partition recommendation)\n\
     reconfig   [--model M] [--model2 M] [--mig 1g|2g|7g] [--profile diurnal|bursty|constant]\n\
                [--rate QPS] [--rate2 QPS] [--period S] [--sla MS] [--requests N] [--seed S]\n\
                [--window S] [--cooldown S] [--repartition S]\n\
                (two colocated tenants, static fair split vs online slice\n\
                reallocation; diurnal tenants run in anti-phase)\n\
     cluster    [--gpus N] [--fleet a100x4,a30x4] [--strategy ff|bfd|frag|both] [--routing jsq|rr]\n\
                [--horizon S] [--seed S] [--reconfig] [--planner greedy|anneal|exact]\n\
                [--migration S] [--repartition S]\n\
                [--trace PATH|azure] [--rate-scale X] [--shards N] [--admission] [--energy]\n\
                [--consolidate] [--faults SPEC] [--interference]\n\
                (multi-GPU DES: a diurnal tenant fleet packed onto a — possibly\n\
                heterogeneous — GPU inventory; FF vs BFD stranded capacity, fleet\n\
                p95/p99/SLA violations, optional online cross-GPU rebalancing.\n\
                --trace streams recorded arrival timestamps (CSV/JSON read in\n\
                bounded-memory chunks; 'azure' = bundled synthetic generator)\n\
                fitted to the horizon and thinned per tenant — arrivals are\n\
                pulled lazily, so million-row trace days replay without being\n\
                materialized. --shards overrides event-heap sharding (0 = auto:\n\
                one shard per tenant↔GPU residency component; 1 = single global\n\
                heap; N = round-robin cap) — outcomes are byte-identical at any\n\
                setting. --rate-scale multiplies the offered load, and\n\
                --admission parks rejected\n\
                tenants' traffic in a pending queue the controller re-packs\n\
                instead of dropping it — implies --reconfig. --energy adds the\n\
                fleet's integrated-energy columns (kJ, J/query, perf/W) and\n\
                --consolidate lets the controller power down drained GPUs\n\
                under sustained low load — implies --reconfig. --faults injects\n\
                a deterministic fault schedule — comma-separated\n\
                kind@T:gN[:DUR[:FACTOR]] with kind in crash|slice|preproc|slow|\n\
                abort (DUR 'inf' = never repaired) plus mtbf:M[,mttr:R] for a\n\
                seeded stochastic background — and runs each packing twice:\n\
                a blind no-recovery baseline vs the [fault] recovery stack\n\
                (detect/retry/hedge/failover), adding availability columns).\n\
                --interference replays under the MIGPerf-calibrated [curves]\n\
                layer: per-(model, profile, batch) latency/power multipliers\n\
                plus a busy-neighbor uncore-contention penalty — the planner\n\
                and energy integrals see contention-deflated capacity.\n\
                --planner picks the rebalancing algorithm (implies --reconfig):\n\
                greedy = the fast amortized-cost heuristic, anneal = budgeted\n\
                simulated annealing seeded from greedy (never worse), exact =\n\
                branch-and-bound ground truth for small fleets (larger fleets\n\
                fall back to anneal). --strategy frag packs by fragmentation-\n\
                gradient descent (demand-aware best-fit variant).\n\
     report     DIR\n\
                (digest of an exported --obs directory: the run fingerprint,\n\
                reconciled totals, sampled-span phase breakdown, the worst\n\
                windows by p95, and the fleet event log)\n\
     energy     [--model M] [--requests N]\n\
                (integrated energy & cost per design point: baseline CPU\n\
                preprocessing vs PREBA's DPU — J/query, QPS/W, queries/$)\n\
     interference\n\
                (flat vs curve-aware provisioning for a latency-SLA tenant\n\
                beside saturating neighbor slices — the failure mode the\n\
                [curves] layer exists to prevent; alias for\n\
                `experiment interference`)\n\
     experiment <fig5|fig6|fig7|fig8|fig9|fig12|fig13|fig14|fig15|fig17|fig18|fig19|fig20|fig21|fig22|table1|reconfig|packing|cluster|energy|faults|interference|optimality|all>\n\
                [--jobs N] [--out DIR]\n\
     list\n\
     \n\
     global: --config FILE (TOML overrides), --fast (smaller request budgets),\n\
             --jobs N (worker threads for experiment sweeps; default: all\n\
             cores; also via PREBA_JOBS). Results are bitwise identical at\n\
             any job count — every simulation is seed-deterministic and the\n\
             sweep engine merges results in job order.\n\
             simulate/cluster: --obs DIR exports observability artifacts\n\
             (windowed JSONL series, sampled request spans, a Chrome\n\
             trace-event timeline for ui.perfetto.dev) without perturbing\n\
             the run — disabled runs are byte-identical. --obs-window S\n\
             sets the series bucket width, --span-sample N samples every\n\
             Nth request's span (deterministic, by index). `[obs]` in the\n\
             TOML sets the same knobs."
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env(&[
        "fast",
        "help",
        "reconfig",
        "admission",
        "energy",
        "consolidate",
        "interference",
    ])?;
    if args.flag("help") || args.command.is_none() {
        println!("{}", usage());
        return Ok(());
    }
    if args.flag("fast") {
        preba::experiments::set_fast(true);
    }
    if let Some(jobs) = args.opt("jobs") {
        let n = jobs
            .parse::<usize>()
            .ok()
            .filter(|&n| n >= 1)
            .ok_or_else(|| anyhow::anyhow!("--jobs expects a positive integer, got '{jobs}'"))?;
        preba::util::par::set_jobs(n);
    }
    let sys = match args.opt("config") {
        Some(path) => PrebaConfig::from_file(path)?,
        None => PrebaConfig::new(),
    };

    match args.command.as_deref().unwrap() {
        "list" => list(),
        "serve" => serve(&args, &sys),
        "simulate" => simulate(&args, &sys),
        "profile" => profile(&args, &sys),
        "plan" => plan(&args),
        "reconfig" => reconfig_cmd(&args, &sys),
        "cluster" => cluster_cmd(&args, &sys),
        "energy" => energy_cmd(&args, &sys),
        "report" => report_cmd(&args),
        "interference" => {
            preba::experiments::interference::run(&sys);
            Ok(())
        }
        "experiment" => experiment(&args, &sys),
        other => {
            anyhow::bail!("unknown command '{other}'\n{}", usage());
        }
    }
}

/// Resolve the `[obs]` TOML section plus the `--obs DIR`, `--obs-window`
/// and `--span-sample` overrides into a driver recording spec and (when
/// enabled) the artifact directory to export into.
fn obs_setup(
    args: &Args,
    sys: &PrebaConfig,
) -> anyhow::Result<(preba::obs::ObsSpec, Option<std::path::PathBuf>)> {
    let mut cfg = sys.obs.clone();
    if let Some(dir) = args.opt("obs") {
        cfg.enabled = true;
        cfg.out_dir = dir.to_string();
    }
    cfg.window_s = args.opt_f64("obs-window", cfg.window_s)?;
    anyhow::ensure!(cfg.window_s > 0.0, "--obs-window must be positive");
    let sample = args.opt_u64("span-sample", cfg.span_sample as u64)?;
    anyhow::ensure!(sample >= 1, "--span-sample must be >= 1");
    cfg.span_sample = sample as usize;
    let dir = cfg.enabled.then(|| std::path::PathBuf::from(&cfg.out_dir));
    Ok((cfg.spec(), dir))
}

/// Per-GPU exporter description from the energy model's class parameters.
fn gpu_desc(em: &preba::energy::EnergyModel, class: &preba::mig::GpuClass) -> preba::obs::GpuDesc {
    let p = em.gpu_params(class);
    preba::obs::GpuDesc {
        name: class.name.to_string(),
        gpcs: class.gpcs,
        gpc_active_w: p.gpc_active_w,
        gpc_idle_w: p.gpc_idle_w,
    }
}

/// `preba report DIR`: digest of an exported obs directory.
fn report_cmd(args: &Args) -> anyhow::Result<()> {
    let dir = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.opt("dir"))
        .ok_or_else(|| anyhow::anyhow!("usage: preba report DIR (an exported --obs directory)"))?;
    preba::obs::report::report(std::path::Path::new(dir))
}

/// `preba plan --model M --sla MS [--len S]`: partition recommendation.
fn plan(args: &Args) -> anyhow::Result<()> {
    let model = parse_model(args)?;
    let sla_ms = args.opt_f64("sla", 50.0)?;
    let len = args.opt_f64("len", preba::mig::planner::default_len(model))?;
    let points = preba::mig::planner::plan(model, sla_ms, len);
    println!("partition plan for {} (p95 <= {sla_ms} ms, len {len} s):\n", model.display());
    let mut t = Table::new(&["partition", "batch", "QPS @SLA", "exec ms", "e2e ms"]);
    for p in &points {
        t.row(&[
            p.partition.name(),
            if p.batch == 0 { "-".into() } else { p.batch.to_string() },
            num(p.qps),
            num(p.exec_ms),
            num(p.e2e_ms),
        ]);
    }
    t.print();
    match preba::mig::planner::recommend(model, sla_ms, len) {
        Some(best) => println!("\nrecommended: {} at batch {}", best.partition.name(), best.batch),
        None => println!("\nno partition can meet this SLA"),
    }
    Ok(())
}

fn parse_model(args: &Args) -> anyhow::Result<ModelId> {
    let name = args.opt("model").ok_or_else(|| anyhow::anyhow!("--model required"))?;
    ModelId::parse(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown model '{name}' (known: {})",
            ModelId::ALL.map(|m| m.name()).join(", ")
        )
    })
}

fn parse_mig(args: &Args) -> anyhow::Result<MigConfig> {
    let s = args.opt_or("mig", "1g");
    MigConfig::parse(s).ok_or_else(|| anyhow::anyhow!("unknown MIG config '{s}'"))
}

fn list() -> anyhow::Result<()> {
    println!("models:");
    let mut t = Table::new(&["name", "display", "kind", "params", "knee(1g)", "knee(7g)"]);
    for m in ModelId::ALL {
        let s = m.spec();
        t.row(&[
            m.name().to_string(),
            m.display().to_string(),
            format!("{:?}", m.kind()),
            format!("{:.1}M", s.params_full as f64 / 1e6),
            s.knee_1g.map(|k| k.to_string()).unwrap_or_else(|| "len-dep".into()),
            s.knee_7g.map(|k| k.to_string()).unwrap_or_else(|| "len-dep".into()),
        ]);
    }
    t.print();
    println!("\nMIG configs: 1g.5gb(7x), 2g.10gb(3x), 7g.40gb(1x)");
    println!("\nexperiments:");
    for (id, _) in preba::experiments::ALL {
        println!("  {id}");
    }
    Ok(())
}

fn serve(args: &Args, sys: &PrebaConfig) -> anyhow::Result<()> {
    let model = parse_model(args)?;
    let preproc = match args.opt_or("preproc", "dpu") {
        "host" | "cpu" => real_driver::RealPreproc::HostRust,
        "dpu" | "pallas" => real_driver::RealPreproc::DpuPallas,
        other => anyhow::bail!("unknown --preproc '{other}' (host|dpu)"),
    };
    let artifacts = args.opt_or("artifacts", &sys.artifacts_dir);
    let mut engine = preba::runtime::Engine::new(artifacts)?;
    let mut cfg = real_driver::RealConfig::new(model, preproc);
    cfg.rate_qps = args.opt_f64("rate", 20.0)?;
    cfg.requests = args.opt_u64("requests", 100)? as usize;
    cfg.seed = args.opt_u64("seed", 7)?;
    println!(
        "serving {} ({} requests @ {} QPS, preproc={:?}) on PJRT[{}]...",
        model.display(),
        cfg.requests,
        cfg.rate_qps,
        preproc,
        engine.platform()
    );
    let out = real_driver::serve(&cfg, sys, &mut engine)?;
    print_run_stats(&out.stats);
    println!(
        "executed {} batches; mean batch {:.2}; output L2 {:.3}",
        out.executed_batches,
        out.stats.batch_sizes.mean(),
        out.output_l2
    );
    Ok(())
}

fn simulate(args: &Args, sys: &PrebaConfig) -> anyhow::Result<()> {
    let model = parse_model(args)?;
    let mig = parse_mig(args)?;
    let preproc = match args.opt_or("preproc", "dpu") {
        "ideal" => PreprocMode::Ideal,
        "cpu" => PreprocMode::Cpu,
        "dpu" => PreprocMode::Dpu,
        other => anyhow::bail!("unknown --preproc '{other}' (ideal|cpu|dpu)"),
    };
    let mut cfg = SimConfig::new(model, mig, preproc);
    cfg.policy = match args.opt_or("policy", "dynamic") {
        "static" => PolicyKind::Static,
        "dynamic" => PolicyKind::Dynamic,
        other => anyhow::bail!("unknown --policy '{other}'"),
    };
    cfg.active_servers = args.opt_u64("servers", mig.vgpus() as u64)? as usize;
    cfg.requests = args.opt_u64("requests", 20_000)? as usize;
    cfg.seed = args.opt_u64("seed", 0xBEEF)?;
    cfg.rate_qps = args.opt_f64("rate", cfg.saturating_rate())?;
    cfg.sla_ms = args.opt_f64("sla", cfg.sla_ms)?;
    if let Some(kind) = args.opt("profile") {
        cfg.profile = Some(
            preba::workload::RateProfile::named(kind, cfg.rate_qps).ok_or_else(|| {
                anyhow::anyhow!("unknown --profile '{kind}' (constant|diurnal|bursty)")
            })?,
        );
    }
    if args.flag("reconfig") {
        cfg.reconfig = Some(preba::mig::ReconfigPolicy::default());
    }
    let (obs_spec, obs_dir) = obs_setup(args, sys)?;
    cfg.obs = obs_spec;
    let mut fp = preba::obs::Fingerprint::new("simulate");
    fp.push("model", model.name());
    fp.push("mig", mig.name());
    fp.push("preproc", format!("{preproc:?}"));
    fp.push("policy", format!("{:?}", cfg.policy));
    fp.push("servers", cfg.active_servers);
    fp.push("requests", cfg.requests);
    fp.push("seed", cfg.seed);
    fp.push("rate_qps", format!("{:.3}", cfg.rate_qps));
    if let Some(kind) = args.opt("profile") {
        fp.push("profile", kind);
    }
    fp.push("reconfig", cfg.reconfig.is_some());
    if cfg.obs.enabled {
        fp.push("obs_window_s", format!("{:.3}", preba::clock::to_secs(cfg.obs.window_ns)));
        fp.push("span_sample", cfg.obs.span_sample);
    }
    println!("{}", fp.line());
    println!(
        "simulating {} on {} ({:?}, {:?}, {} servers, {:.1} QPS offered{})...",
        model.display(),
        mig.name(),
        preproc,
        cfg.policy,
        cfg.active_servers,
        cfg.rate_qps,
        if cfg.reconfig.is_some() { ", online reconfig" } else { "" }
    );
    let out = sim_driver::run(&cfg, sys);
    print_run_stats(&out.stats);
    println!(
        "cpu util {:.1}%  gpu util {:.1}%  dpu util {}  pcie {:.2} GB/s",
        100.0 * out.cpu_util,
        100.0 * out.gpu_util,
        out.dpu_util.map(|u| format!("{:.1}%", 100.0 * u)).unwrap_or_else(|| "-".into()),
        out.pcie_gbps
    );
    if cfg.reconfig.is_some() {
        println!(
            "reconfigs {}  outage {:.1} ms  final partition {}  SLA viol {:.2}% (sla {} ms)",
            out.reconfigs,
            out.reconfig_downtime as f64 * 1e-6,
            out.final_mig.name(),
            100.0 * out.stats.sla_violation_frac(cfg.sla_ms),
            cfg.sla_ms
        );
        for ev in &out.reconfig_events {
            println!(
                "  t={:.2}s -> {} (predicted gain {:.1} ms)",
                preba::clock::to_secs(ev.at),
                ev.plan,
                ev.predicted_gain_ms
            );
        }
    }
    if let Some(dir) = &obs_dir {
        let log = out.obs.as_ref().expect("obs enabled implies a captured log");
        let em = preba::energy::EnergyModel::new(&sys.energy);
        let marks = out
            .reconfig_events
            .iter()
            .map(|ev| preba::obs::EventMark {
                at: ev.at,
                gpu: Some(0),
                kind: "reconfig".into(),
                detail: format!("{} (predicted gain {:.1} ms)", ev.plan, ev.predicted_gain_ms),
            })
            .collect();
        let input = preba::obs::ExportInput {
            log,
            fp: &fp,
            horizon: out.horizon,
            gpus: vec![gpu_desc(&em, &preba::mig::GpuClass::A100)],
            tenants: vec![model.display().to_string()],
            marks,
        };
        let files = preba::obs::export::export(dir, &input)?;
        println!(
            "obs: {} artifacts -> {} (digest: preba report {})",
            files.len(),
            dir.display(),
            dir.display()
        );
    }
    Ok(())
}

/// `preba reconfig`: two colocated tenants on one partition — static fair
/// split vs online slice reallocation (`mig::reconfig`), side by side.
fn reconfig_cmd(args: &Args, sys: &PrebaConfig) -> anyhow::Result<()> {
    use preba::server::multi::{self, MultiConfig, TenantDemand};
    use preba::workload::RateProfile;

    let parse_model_or = |key: &str, default: ModelId| -> anyhow::Result<ModelId> {
        match args.opt(key) {
            None => Ok(default),
            Some(name) => ModelId::parse(name)
                .ok_or_else(|| anyhow::anyhow!("unknown model '{name}' for --{key}")),
        }
    };
    let model = parse_model_or("model", ModelId::SwinTransformer)?;
    let model2 = parse_model_or("model2", model)?;
    let mig = parse_mig(args)?;
    let sla_ms = args.opt_f64("sla", 25.0)?;
    let period = args.opt_f64("period", 6.0)?;
    let kind = args.opt_or("profile", "diurnal");
    // Default per-tenant mean demand: ~2.6 slices' worth at the sustained
    // (knee) operating point — peaks overrun a fair split, totals fit.
    let unit = |m: ModelId| {
        let len = preba::mig::planner::default_len(m);
        preba::mig::ServiceModel::new(m.spec(), mig.gpcs_per_vgpu()).plateau_qps(len) * 0.9
    };
    let rate = args.opt_f64("rate", 2.6 * unit(model))?;
    let rate2 = args.opt_f64("rate2", 2.6 * unit(model2))?;
    let requests = args.opt_u64("requests", 12_000)? as usize;
    let seed = args.opt_u64("seed", 0x7EC0)?;
    let policy = preba::mig::ReconfigPolicy {
        window_s: args.opt_f64("window", 0.5)?,
        cooldown_s: args.opt_f64("cooldown", 1.0)?,
        repartition_s: args.opt_f64("repartition", 0.1)?,
        ..Default::default()
    };

    let mk_profile = |base: f64, phase_frac: f64| -> anyhow::Result<Option<RateProfile>> {
        Ok(match kind {
            "constant" => None,
            "diurnal" => Some(RateProfile::Diurnal {
                base_qps: base,
                amplitude: 0.577,
                period_s: period,
                phase_frac,
            }),
            "bursty" => RateProfile::named("bursty", base),
            other => anyhow::bail!("unknown --profile '{other}' (constant|diurnal|bursty)"),
        })
    };
    let demands = vec![
        TenantDemand { model, rate_qps: rate, sla_ms },
        TenantDemand { model: model2, rate_qps: rate2, sla_ms },
    ];
    let mut tenants = multi::place_tenants(&demands, mig, 0.85)?;
    tenants[0].profile = mk_profile(rate, 0.0)?;
    tenants[1].profile = mk_profile(rate2, 0.5)?;
    let static_alloc: Vec<usize> = tenants.iter().map(|t| t.vgpus).collect();
    println!(
        "{} + {} on {} ({kind}, {:.0}/{:.0} QPS mean, sla {sla_ms} ms, static split {:?})\n",
        model.display(),
        model2.display(),
        mig.name(),
        rate,
        rate2,
        static_alloc
    );

    let mut cfg = MultiConfig {
        mig,
        tenants,
        preproc: preba::server::PreprocMode::Ideal,
        policy: PolicyKind::Dynamic,
        requests,
        seed,
        warmup_frac: 0.05,
        reconfig: None,
    };
    let static_out = multi::run(&cfg, sys)?;
    cfg.reconfig = Some(policy);
    let online_out = multi::run(&cfg, sys)?;

    let mut t = Table::new(&["mode", "tenant", "QPS", "p95 ms", "viol %"]);
    for (mode, out) in [("static", &static_out), ("online", &online_out)] {
        for (m, stats) in &out.per_tenant {
            t.row(&[
                mode.to_string(),
                m.display().to_string(),
                num(stats.throughput_qps()),
                num(stats.p95_ms()),
                num(stats.sla_violation_frac(sla_ms) * 100.0),
            ]);
        }
    }
    t.print();
    println!(
        "\nonline: {} reallocations, {:.1} ms total transfer outage",
        online_out.reconfigs,
        online_out.reconfig_downtime as f64 * 1e-6
    );
    for ev in &online_out.reconfig_events {
        println!(
            "  t={:.2}s -> {} (rates {:.0}/{:.0} QPS, predicted gain {:.1} ms)",
            preba::clock::to_secs(ev.at),
            ev.plan,
            ev.rates.first().copied().unwrap_or(0.0),
            ev.rates.get(1).copied().unwrap_or(0.0),
            ev.predicted_gain_ms
        );
    }
    Ok(())
}

/// `preba cluster`: the diurnal tenant fleet from the `cluster`
/// experiment packed onto a (possibly heterogeneous) GPU inventory —
/// first-fit vs best-fit-decreasing side by side (stranded capacity and
/// fleet tails), optionally with online cross-GPU rebalancing, recorded
/// trace replay, and admission control.
fn cluster_cmd(args: &Args, sys: &PrebaConfig) -> anyhow::Result<()> {
    use preba::experiments::cluster::diurnal_fleet;
    use preba::fault::{FaultSchedule, FaultSpec};
    use preba::mig::{GpuClass, PackStrategy, PlannerKind};
    use preba::server::cluster::{self, ClusterConfig, Routing};
    use preba::workload::StreamSpec;

    // --interference: replay under the MIGPerf-calibrated `[curves]`
    // layer — per-(model, profile, batch) latency/power multipliers plus
    // the busy-neighbor contention penalty (see `preba interference`).
    let curved_sys;
    let sys = if args.flag("interference") {
        curved_sys = preba::experiments::interference::curved(sys);
        &curved_sys
    } else {
        sys
    };

    let fleet: Vec<GpuClass> = match args.opt("fleet") {
        Some(spec) => sys.cluster.parse_fleet(spec)?,
        None => match args.opt("gpus") {
            Some(_) => {
                let n = args.opt_u64("gpus", sys.cluster.gpus as u64)? as usize;
                anyhow::ensure!(n >= 1, "--gpus must be >= 1");
                vec![sys.cluster.class("a100").expect("a100 preset"); n]
            }
            None => sys.cluster.default_fleet()?,
        },
    };
    let n_gpus = fleet.len();
    let horizon_s = args.opt_f64("horizon", sys.cluster.horizon_s)?;
    anyhow::ensure!(horizon_s > 0.0, "--horizon must be positive");
    let seed = args.opt_u64("seed", 0xC1A0)?;
    // Event-heap sharding: 0 = auto (per residency component), 1 = the
    // single global heap, N = round-robin cap. Byte-identical outcomes
    // at every setting — this is a performance knob, not a semantic one.
    let shards = args.opt_u64("shards", sys.cluster.shards as u64)? as usize;
    let routing_s = args.opt_or("routing", "jsq");
    let routing = Routing::parse(routing_s)
        .ok_or_else(|| anyhow::anyhow!("unknown --routing '{routing_s}' (jsq|rr)"))?;
    let strategies: Vec<PackStrategy> = match args.opt_or("strategy", "both") {
        "ff" | "first-fit" => vec![PackStrategy::FirstFit],
        "bfd" | "best-fit" => vec![PackStrategy::BestFit],
        "frag" | "frag-gradient" => vec![PackStrategy::FragGradient],
        "both" => vec![PackStrategy::FirstFit, PackStrategy::BestFit],
        other => anyhow::bail!("unknown --strategy '{other}' (ff|bfd|frag|both)"),
    };
    let admission = args.flag("admission");
    let consolidate = args.flag("consolidate");
    let energy_cols = args.flag("energy");
    // Fault injection: --faults SPEC, falling back to `[fault] spec` from
    // the TOML. Each packing strategy then runs twice — a blind
    // no-recovery baseline vs the `[fault]` recovery stack — at identical
    // schedule, load and seed.
    let faults_spec = args
        .opt("faults")
        .map(str::to_string)
        .or_else(|| (!sys.fault.spec.is_empty()).then(|| sys.fault.spec.clone()));
    let fault_sched = match &faults_spec {
        None => None,
        Some(spec) => {
            let sched = FaultSchedule::parse(spec, n_gpus, horizon_s, seed)?;
            anyhow::ensure!(!sched.is_empty(), "--faults '{spec}' produced no fault events");
            Some(sched)
        }
    };
    // --planner implies --reconfig: selecting an algorithm only makes
    // sense when the rebalancing controller runs.
    let planner_opt = args.opt("planner");
    let reconfig = if args.flag("reconfig") || admission || consolidate || planner_opt.is_some() {
        let repartition_s = args.opt_f64("repartition", sys.cluster.repartition_s)?;
        let migration_s = args.opt_f64("migration", sys.cluster.migration_s)?;
        anyhow::ensure!(
            migration_s >= repartition_s,
            "--migration ({migration_s}s) must cost at least --repartition ({repartition_s}s): \
             the planner assumes crossing a GPU is the expensive move"
        );
        let planner = match planner_opt {
            Some(name) => PlannerKind::parse(name).ok_or_else(|| {
                anyhow::anyhow!("unknown --planner '{name}' (greedy|anneal|exact)")
            })?,
            None => sys.reconfig.planner_kind()?,
        };
        Some(preba::mig::ReconfigPolicy {
            repartition_s,
            migration_s,
            planner,
            ..preba::experiments::cluster::policy(sys)
        })
    } else {
        None
    };

    // Recorded-trace replay, streamed: each tenant carries a cloneable
    // [`StreamSpec`] and the DES pulls arrivals lazily, so a million-row
    // trace day replays in bounded memory. The recorded timeline is
    // first fitted onto the simulated horizon (every tenant replays the
    // SAME span, so the cross-tenant burst/diurnal alignment survives),
    // then per-tenant THINNED toward that tenant's mean rate
    // (× --rate-scale) without re-timing the surviving arrivals.
    // Thinning cannot invent traffic: a tenant asking more than the
    // recorded density replays the full trace.
    let rate_scale = args.opt_f64("rate-scale", 1.0)?;
    anyhow::ensure!(rate_scale > 0.0, "--rate-scale must be positive");
    let mut tenants = diurnal_fleet(n_gpus, horizon_s);
    let trace = args.opt("trace").map(|spec| {
        // Dense enough that per-tenant thinning can hit every tenant's
        // target rate.
        let max_qps = tenants.iter().map(|t| t.rate_qps).fold(0.0f64, f64::max) * rate_scale;
        match spec {
            "azure" => StreamSpec::azure(seed ^ 0xA27E, horizon_s, max_qps),
            path => StreamSpec::file(path),
        }
    });
    if let Some(base) = &trace {
        tenants = tenants
            .into_iter()
            .enumerate()
            .map(|(ti, t)| {
                let qps = t.rate_qps * rate_scale;
                let spec = base
                    .clone()
                    .fit_duration(horizon_s)
                    .thin_to_qps(qps, seed ^ (0x7ACE_0000 + ti as u64));
                t.with_stream(spec)
            })
            .collect::<anyhow::Result<_>>()?;
    }
    let total_reqs: usize = tenants.iter().map(|t| t.requests).sum();
    let fleet_desc = fleet.iter().map(|c| c.name).collect::<Vec<_>>().join(",");
    let (obs_spec, obs_dir) = obs_setup(args, sys)?;
    let mut fp = preba::obs::Fingerprint::new("cluster");
    fp.push("seed", seed);
    fp.push("fleet", &fleet_desc);
    fp.push("horizon_s", format!("{horizon_s:.3}"));
    fp.push("routing", routing.label());
    fp.push("shards", if shards == 0 { "auto".to_string() } else { shards.to_string() });
    fp.push("planner", reconfig.as_ref().map_or("off", |p| p.planner.label()));
    fp.push("admission", admission);
    fp.push("consolidate", consolidate);
    fp.push("interference", args.flag("interference"));
    fp.push("rate_scale", format!("{rate_scale:.3}"));
    if let Some(spec) = &faults_spec {
        fp.push("faults", spec);
    }
    if let Some(tr) = args.opt("trace") {
        fp.push("trace", tr);
    }
    if obs_spec.enabled {
        fp.push("obs_window_s", format!("{:.3}", preba::clock::to_secs(obs_spec.window_ns)));
        fp.push("span_sample", obs_spec.span_sample);
    }
    println!("{}", fp.line());
    println!(
        "cluster of {n_gpus} GPUs [{fleet_desc}], {} tenants ({total_reqs} requests over \
         ~{horizon_s} s, routing {}{}{}{}{}{})\n",
        tenants.len(),
        routing.label(),
        if trace.is_some() { ", trace replay" } else { "" },
        match &reconfig {
            Some(p) => format!(", online cross-GPU rebalancing [{}]", p.planner.label()),
            None => String::new(),
        },
        if admission { ", admission control" } else { "" },
        if consolidate { ", energy consolidation" } else { "" },
        match &fault_sched {
            Some(s) => format!(", {} injected faults", s.len()),
            None => String::new(),
        }
    );

    let mut headers = vec![
        "packing", "admitted", "asked", "stranded %", "worst p95 ms", "worst p99 ms", "viol %",
        "dropped", "deferred", "served late", "rebalances", "migrations",
    ];
    if energy_cols {
        headers.extend(["fleet kJ", "J/query", "perf/W", "GPU-off s", "power-downs"]);
    }
    if fault_sched.is_some() {
        headers.extend(["avail %", "timed out", "retries", "hedges", "degraded", "MTTR s"]);
    }
    let mut t = Table::new(&headers);
    // Event detail lines are buffered so they print AFTER the summary
    // table whose rebalance/migration columns they annotate.
    let mut timeline: Vec<String> = Vec::new();
    // With faults on, each strategy becomes an A/B pair at identical
    // schedule/load/seed; without, the single fault-free run.
    let runs: Vec<(PackStrategy, Option<FaultSpec>)> = strategies
        .iter()
        .flat_map(|&strategy| match &fault_sched {
            None => vec![(strategy, None)],
            Some(sched) => vec![
                (strategy, Some(FaultSpec::baseline(sched.clone()))),
                (strategy, Some(FaultSpec::recovering(sched.clone(), sys.fault.recovery()))),
            ],
        })
        .collect();
    let runs_n = runs.len();
    for (strategy, faults) in runs {
        let label = match &faults {
            None => strategy.label().to_string(),
            Some(f) => format!(
                "{}/{}",
                strategy.label(),
                if f.recovery.is_some() { "recovery" } else { "baseline" }
            ),
        };
        let mut cfg = ClusterConfig::builder()
            .fleet(fleet.clone())
            .strategy(strategy)
            .tenants(tenants.clone())
            .routing(routing)
            .seed(seed)
            .admission(admission)
            .consolidate(consolidate)
            .build();
        cfg.reconfig = reconfig.clone();
        cfg.faults = faults;
        cfg.shards = (shards != 0).then_some(shards);
        cfg.obs = obs_spec;
        let out = cluster::run(&cfg, sys)?;
        let mut row = vec![
            label.clone(),
            out.packing.admitted_gpcs().to_string(),
            out.packing.asked_gpcs().to_string(),
            num(out.packing.fragmentation() * 100.0),
            num(out.worst_p95_ms()),
            num(out.worst_p99_ms()),
            num(out.max_violation_frac(&cfg.tenants) * 100.0),
            out.dropped.iter().sum::<u64>().to_string(),
            out.deferred.iter().sum::<u64>().to_string(),
            out.deferred_served.iter().sum::<u64>().to_string(),
            out.reconfigs.to_string(),
            out.migrations.to_string(),
        ];
        if energy_cols {
            row.extend([
                num(out.energy.total_j() / 1e3),
                num(out.joules_per_query()),
                num(out.perf_per_watt()),
                num(out.gpu_off_s),
                out.consolidations.to_string(),
            ]);
        }
        if fault_sched.is_some() {
            row.extend([
                num(out.availability_frac() * 100.0),
                out.timed_out_total().to_string(),
                out.retries.iter().sum::<u64>().to_string(),
                out.hedges.iter().sum::<u64>().to_string(),
                out.served_degraded.iter().sum::<u64>().to_string(),
                num(out.mttr_s),
            ]);
        }
        t.row(&row);
        for ev in &out.reconfig_events {
            timeline.push(format!(
                "  [{label}] t={:.2}s -> {} moves ({} migration, predicted gain {:.1} ms)",
                preba::clock::to_secs(ev.at),
                ev.moves.len(),
                ev.migrations(),
                ev.predicted_gain_ms
            ));
        }
        for ev in &out.consolidation_events {
            timeline.push(format!(
                "  [{label}] t={:.2}s -> {} GPU{} (retired {}, moved {})",
                preba::clock::to_secs(ev.at),
                if ev.powered_down { "power-down" } else { "wake" },
                ev.gpu,
                ev.retired,
                ev.moved
            ));
        }
        for r in &out.fault_records {
            timeline.push(format!(
                "  [{label}] t={:.2}s {} on gpu{}{} -> detected {}, repaired {}",
                r.at_s,
                r.kind.label(),
                r.gpu,
                if r.skipped { " (skipped: unit already down)" } else { "" },
                r.detected_s.map_or("never".into(), |d| format!("{d:.2}s")),
                r.repaired_s.map_or("never".into(), |d| format!("{d:.2}s")),
            ));
        }
        if let Some(dir) = &obs_dir {
            // One artifact set per run; A/B pairs land in sibling subdirs
            // (`bfd-recovery/`, `bfd-baseline/`, ...).
            let sub = if runs_n > 1 { dir.join(label.replace('/', "-")) } else { dir.clone() };
            let mut run_fp = fp.clone();
            run_fp.push("strategy", strategy.label());
            if let Some(f) = &cfg.faults {
                run_fp.push("recovery", f.recovery.is_some());
            }
            let log = out.obs.as_ref().expect("obs enabled implies a captured log");
            let em = preba::energy::EnergyModel::new(&sys.energy);
            let mut marks = Vec::new();
            for ev in &out.reconfig_events {
                marks.push(preba::obs::EventMark {
                    at: ev.at,
                    gpu: None,
                    kind: "reconfig".into(),
                    detail: format!(
                        "{} moves ({} migration, predicted gain {:.1} ms)",
                        ev.moves.len(),
                        ev.migrations(),
                        ev.predicted_gain_ms
                    ),
                });
            }
            for ev in &out.consolidation_events {
                marks.push(preba::obs::EventMark {
                    at: ev.at,
                    gpu: Some(ev.gpu),
                    kind: if ev.powered_down { "power-down" } else { "wake" }.into(),
                    detail: format!("retired {}, moved {}", ev.retired, ev.moved),
                });
            }
            for r in &out.fault_records {
                let mark = |at_s: f64, kind: &str, detail: &str| preba::obs::EventMark {
                    at: preba::clock::secs(at_s),
                    gpu: Some(r.gpu),
                    kind: kind.into(),
                    detail: detail.into(),
                };
                marks.push(mark(
                    r.at_s,
                    r.kind.label(),
                    if r.skipped { "skipped: unit already down" } else { "injected" },
                ));
                if let Some(d) = r.detected_s {
                    marks.push(mark(d, "detect", r.kind.label()));
                }
                if let Some(d) = r.repaired_s {
                    marks.push(mark(d, "repair", r.kind.label()));
                }
            }
            let input = preba::obs::ExportInput {
                log,
                fp: &run_fp,
                horizon: out.horizon,
                gpus: fleet.iter().map(|c| gpu_desc(&em, c)).collect(),
                tenants: cfg.tenants.iter().map(|t| t.model.display().to_string()).collect(),
                marks,
            };
            let files = preba::obs::export::export(&sub, &input)?;
            timeline.push(format!(
                "  [{label}] obs: {} artifacts -> {} (digest: preba report {})",
                files.len(),
                sub.display(),
                sub.display()
            ));
        }
    }
    t.print();
    for line in timeline {
        println!("{line}");
    }
    Ok(())
}

/// `preba energy`: integrated energy & cost per design point — baseline
/// (CPU preprocessing) vs PREBA (DPU) at saturation, for one model or
/// all of them. The same measurement `preba experiment energy` sweeps,
/// without the cluster sections.
fn energy_cmd(args: &Args, sys: &PrebaConfig) -> anyhow::Result<()> {
    use preba::experiments::energy::{mean_w, measure, measure_all};

    let requests = args.opt_u64("requests", 4000)? as usize;
    // The measurement is the energy experiment's section-1 sweep
    // (parallel over the job pool); a single --model measures just its
    // own pair.
    let measured: Vec<(ModelId, _, _)> = match args.opt("model") {
        None => measure_all(requests, sys),
        Some(name) => {
            let model = ModelId::parse(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown model '{name}' (known: {})",
                    ModelId::ALL.map(|m| m.name()).join(", ")
                )
            })?;
            vec![(
                model,
                measure(model, PreprocMode::Cpu, requests, sys),
                measure(model, PreprocMode::Dpu, requests, sys),
            )]
        }
    };
    let tco = preba::energy::TcoModel::new(&sys.tco);
    println!(
        "integrated energy at saturation on 1g.5gb(7x) ({requests} requests per design point)\n"
    );
    let mut t = Table::new(&[
        "model", "design", "QPS", "mean W", "J/query", "QPS/W", "Mqueries/$", "perf/W gain",
    ]);
    for (model, base, preba_out) in &measured {
        let gain = preba_out.stats.perf_per_watt() / base.stats.perf_per_watt().max(1e-12);
        for (label, o, fpga, g) in
            [("baseline", base, false, String::new()), ("PREBA", preba_out, true, num(gain))]
        {
            let report = tco.evaluate_watts(o.qps(), mean_w(o), fpga);
            t.row(&[
                model.display().to_string(),
                label.to_string(),
                num(o.qps()),
                num(mean_w(o)),
                num(o.stats.joules_per_query()),
                num(o.stats.perf_per_watt()),
                num(report.queries_per_usd / 1e6),
                g,
            ]);
        }
    }
    t.print();
    println!(
        "\n(paper §6.2/§6.3: ~3.5x energy-efficiency, ~3.0x cost-efficiency on average; \
         fleet-scale energy: `preba cluster --energy [--consolidate]`)"
    );
    Ok(())
}

fn profile(args: &Args, sys: &PrebaConfig) -> anyhow::Result<()> {
    let model = parse_model(args)?;
    let mig = parse_mig(args)?;
    let len = args.opt_f64("len", 2.5)?;
    let mut rng = preba::util::Rng::new(42);
    let batches = preba::profiler::sweep_batches(256);
    let curve = preba::profiler::profile_curve(
        model.spec(),
        mig.gpcs_per_vgpu(),
        len,
        &batches,
        80,
        &mut rng,
    );
    let knee = preba::profiler::find_knee(&curve, sys.batching.knee_frac);
    let mut t = Table::new(&["batch", "per-vGPU QPS", "p95 ms", "util %", ""]);
    for p in &curve {
        t.row(&[
            p.batch.to_string(),
            num(p.qps),
            num(p.p95_ms),
            num(p.util * 100.0),
            if p.batch == knee.batch { "<-- Batch_knee".into() } else { String::new() },
        ]);
    }
    t.print();
    println!(
        "\nBatch_knee={} Time_knee={:.1} ms -> Batch_max={}, Time_queue={:.2} ms on {}",
        knee.batch,
        knee.p95_ms,
        knee.batch,
        knee.mean_ms / mig.vgpus() as f64,
        mig.name()
    );
    Ok(())
}

fn experiment(args: &Args, sys: &PrebaConfig) -> anyhow::Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("experiment id required (or 'all')"))?;
    if let Some(dir) = args.opt("out") {
        preba::util::bench::set_results_dir(dir);
    }
    if id == "all" {
        // Run the whole suite through the job pool. Each worker captures
        // its experiment's report block; blocks are printed in registry
        // order, so stdout and every results/*.json file are bitwise
        // identical to a --jobs 1 run.
        let blocks = preba::util::par::run_jobs(preba::experiments::ALL.len(), |i| {
            let (name, f) = preba::experiments::ALL[i];
            preba::util::bench::capture_begin();
            f(sys);
            (name, preba::util::bench::capture_end())
        });
        for (name, text) in blocks {
            println!("\n########## {name} ##########");
            print!("{text}");
        }
        return Ok(());
    }
    let f = preba::experiments::by_id(id)
        .ok_or_else(|| anyhow::anyhow!("unknown experiment '{id}' (see `preba list`)"))?;
    f(sys);
    Ok(())
}

fn print_run_stats(stats: &preba::metrics::RunStats) {
    let (pre, bat, disp, exec) = stats.breakdown_ms();
    println!(
        "completed {}  throughput {:.1} QPS  mean {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
        stats.completed,
        stats.throughput_qps(),
        stats.mean_ms(),
        stats.p95_ms(),
        stats.e2e_ms.p99()
    );
    println!(
        "breakdown: preprocess {pre:.2} ms | batching {bat:.2} ms | queue {disp:.2} ms | execute {exec:.2} ms"
    );
}
