//! `preba` — the PREBA MIG inference server CLI (L3 leader entrypoint).
//!
//! Subcommands:
//! * `serve`      — run the real-PJRT serving pipeline on AOT artifacts.
//! * `simulate`   — one DES run with explicit knobs (model/mig/preproc/...).
//! * `profile`    — offline Batch_knee profiling for a model+MIG config.
//! * `experiment` — regenerate a paper figure/table (`all` for everything).
//! * `list`       — enumerate models, MIG configs and experiments.

use preba::cli::Args;
use preba::config::PrebaConfig;
use preba::mig::MigConfig;
use preba::models::ModelId;
use preba::server::{real_driver, sim_driver, PolicyKind, PreprocMode, SimConfig};
use preba::util::table::{num, Table};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: preba <serve|simulate|profile|experiment|list> [options]\n\
     \n\
     serve      --model M [--preproc host|dpu] [--rate QPS] [--requests N] [--artifacts DIR]\n\
     simulate   --model M [--mig 1g|2g|7g] [--preproc ideal|cpu|dpu] [--policy static|dynamic]\n\
                [--servers N] [--rate QPS] [--requests N] [--seed S]\n\
     profile    --model M [--mig 1g|2g|7g] [--len SECONDS]\n\
     plan       --model M [--sla MS] [--len SECONDS]   (partition recommendation)\n\
     experiment <fig5|fig6|fig7|fig8|fig9|fig12|fig13|fig14|fig15|fig17|fig18|fig19|fig20|fig21|fig22|table1|all>\n\
                [--jobs N] [--out DIR]\n\
     list\n\
     \n\
     global: --config FILE (TOML overrides), --fast (smaller request budgets),\n\
             --jobs N (worker threads for experiment sweeps; default: all\n\
             cores; also via PREBA_JOBS). Results are bitwise identical at\n\
             any job count — every simulation is seed-deterministic and the\n\
             sweep engine merges results in job order."
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env(&["fast", "help"])?;
    if args.flag("help") || args.command.is_none() {
        println!("{}", usage());
        return Ok(());
    }
    if args.flag("fast") {
        std::env::set_var("PREBA_FAST", "1");
    }
    if let Some(jobs) = args.opt("jobs") {
        jobs.parse::<usize>()
            .ok()
            .filter(|&n| n >= 1)
            .ok_or_else(|| anyhow::anyhow!("--jobs expects a positive integer, got '{jobs}'"))?;
        std::env::set_var("PREBA_JOBS", jobs);
    }
    let sys = match args.opt("config") {
        Some(path) => PrebaConfig::from_file(path)?,
        None => PrebaConfig::new(),
    };

    match args.command.as_deref().unwrap() {
        "list" => list(),
        "serve" => serve(&args, &sys),
        "simulate" => simulate(&args, &sys),
        "profile" => profile(&args, &sys),
        "plan" => plan(&args),
        "experiment" => experiment(&args, &sys),
        other => {
            anyhow::bail!("unknown command '{other}'\n{}", usage());
        }
    }
}

/// `preba plan --model M --sla MS [--len S]`: partition recommendation.
fn plan(args: &Args) -> anyhow::Result<()> {
    let model = parse_model(args)?;
    let sla_ms = args.opt_f64("sla", 50.0)?;
    let len = args.opt_f64("len", preba::mig::planner::default_len(model))?;
    let points = preba::mig::planner::plan(model, sla_ms, len);
    println!("partition plan for {} (p95 <= {sla_ms} ms, len {len} s):\n", model.display());
    let mut t = Table::new(&["partition", "batch", "QPS @SLA", "exec ms", "e2e ms"]);
    for p in &points {
        t.row(&[
            p.partition.name(),
            if p.batch == 0 { "-".into() } else { p.batch.to_string() },
            num(p.qps),
            num(p.exec_ms),
            num(p.e2e_ms),
        ]);
    }
    t.print();
    match preba::mig::planner::recommend(model, sla_ms, len) {
        Some(best) => println!("\nrecommended: {} at batch {}", best.partition.name(), best.batch),
        None => println!("\nno partition can meet this SLA"),
    }
    Ok(())
}

fn parse_model(args: &Args) -> anyhow::Result<ModelId> {
    let name = args.opt("model").ok_or_else(|| anyhow::anyhow!("--model required"))?;
    ModelId::parse(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown model '{name}' (known: {})",
            ModelId::ALL.map(|m| m.name()).join(", ")
        )
    })
}

fn parse_mig(args: &Args) -> anyhow::Result<MigConfig> {
    let s = args.opt_or("mig", "1g");
    MigConfig::parse(s).ok_or_else(|| anyhow::anyhow!("unknown MIG config '{s}'"))
}

fn list() -> anyhow::Result<()> {
    println!("models:");
    let mut t = Table::new(&["name", "display", "kind", "params", "knee(1g)", "knee(7g)"]);
    for m in ModelId::ALL {
        let s = m.spec();
        t.row(&[
            m.name().to_string(),
            m.display().to_string(),
            format!("{:?}", m.kind()),
            format!("{:.1}M", s.params_full as f64 / 1e6),
            s.knee_1g.map(|k| k.to_string()).unwrap_or_else(|| "len-dep".into()),
            s.knee_7g.map(|k| k.to_string()).unwrap_or_else(|| "len-dep".into()),
        ]);
    }
    t.print();
    println!("\nMIG configs: 1g.5gb(7x), 2g.10gb(3x), 7g.40gb(1x)");
    println!("\nexperiments:");
    for (id, _) in preba::experiments::ALL {
        println!("  {id}");
    }
    Ok(())
}

fn serve(args: &Args, sys: &PrebaConfig) -> anyhow::Result<()> {
    let model = parse_model(args)?;
    let preproc = match args.opt_or("preproc", "dpu") {
        "host" | "cpu" => real_driver::RealPreproc::HostRust,
        "dpu" | "pallas" => real_driver::RealPreproc::DpuPallas,
        other => anyhow::bail!("unknown --preproc '{other}' (host|dpu)"),
    };
    let artifacts = args.opt_or("artifacts", &sys.artifacts_dir);
    let mut engine = preba::runtime::Engine::new(artifacts)?;
    let mut cfg = real_driver::RealConfig::new(model, preproc);
    cfg.rate_qps = args.opt_f64("rate", 20.0)?;
    cfg.requests = args.opt_u64("requests", 100)? as usize;
    cfg.seed = args.opt_u64("seed", 7)?;
    println!(
        "serving {} ({} requests @ {} QPS, preproc={:?}) on PJRT[{}]...",
        model.display(),
        cfg.requests,
        cfg.rate_qps,
        preproc,
        engine.platform()
    );
    let out = real_driver::serve(&cfg, sys, &mut engine)?;
    print_run_stats(&out.stats);
    println!(
        "executed {} batches; mean batch {:.2}; output L2 {:.3}",
        out.executed_batches,
        out.stats.batch_sizes.mean(),
        out.output_l2
    );
    Ok(())
}

fn simulate(args: &Args, sys: &PrebaConfig) -> anyhow::Result<()> {
    let model = parse_model(args)?;
    let mig = parse_mig(args)?;
    let preproc = match args.opt_or("preproc", "dpu") {
        "ideal" => PreprocMode::Ideal,
        "cpu" => PreprocMode::Cpu,
        "dpu" => PreprocMode::Dpu,
        other => anyhow::bail!("unknown --preproc '{other}' (ideal|cpu|dpu)"),
    };
    let mut cfg = SimConfig::new(model, mig, preproc);
    cfg.policy = match args.opt_or("policy", "dynamic") {
        "static" => PolicyKind::Static,
        "dynamic" => PolicyKind::Dynamic,
        other => anyhow::bail!("unknown --policy '{other}'"),
    };
    cfg.active_servers = args.opt_u64("servers", mig.vgpus() as u64)? as usize;
    cfg.requests = args.opt_u64("requests", 20_000)? as usize;
    cfg.seed = args.opt_u64("seed", 0xBEEF)?;
    cfg.rate_qps = args.opt_f64("rate", cfg.saturating_rate())?;
    println!(
        "simulating {} on {} ({:?}, {:?}, {} servers, {:.1} QPS offered)...",
        model.display(),
        mig.name(),
        preproc,
        cfg.policy,
        cfg.active_servers,
        cfg.rate_qps
    );
    let out = sim_driver::run(&cfg, sys);
    print_run_stats(&out.stats);
    println!(
        "cpu util {:.1}%  gpu util {:.1}%  dpu util {}  pcie {:.2} GB/s",
        100.0 * out.cpu_util,
        100.0 * out.gpu_util,
        out.dpu_util.map(|u| format!("{:.1}%", 100.0 * u)).unwrap_or_else(|| "-".into()),
        out.pcie_gbps
    );
    Ok(())
}

fn profile(args: &Args, sys: &PrebaConfig) -> anyhow::Result<()> {
    let model = parse_model(args)?;
    let mig = parse_mig(args)?;
    let len = args.opt_f64("len", 2.5)?;
    let mut rng = preba::util::Rng::new(42);
    let batches = preba::profiler::sweep_batches(256);
    let curve =
        preba::profiler::profile_curve(model.spec(), mig.gpcs_per_vgpu(), len, &batches, 80, &mut rng);
    let knee = preba::profiler::find_knee(&curve, sys.batching.knee_frac);
    let mut t = Table::new(&["batch", "per-vGPU QPS", "p95 ms", "util %", ""]);
    for p in &curve {
        t.row(&[
            p.batch.to_string(),
            num(p.qps),
            num(p.p95_ms),
            num(p.util * 100.0),
            if p.batch == knee.batch { "<-- Batch_knee".into() } else { String::new() },
        ]);
    }
    t.print();
    println!(
        "\nBatch_knee={} Time_knee={:.1} ms -> Batch_max={}, Time_queue={:.2} ms on {}",
        knee.batch,
        knee.p95_ms,
        knee.batch,
        knee.mean_ms / mig.vgpus() as f64,
        mig.name()
    );
    Ok(())
}

fn experiment(args: &Args, sys: &PrebaConfig) -> anyhow::Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("experiment id required (or 'all')"))?;
    if let Some(dir) = args.opt("out") {
        std::env::set_var("PREBA_RESULTS_DIR", dir);
    }
    if id == "all" {
        // Run the whole suite through the job pool. Each worker captures
        // its experiment's report block; blocks are printed in registry
        // order, so stdout and every results/*.json file are bitwise
        // identical to a --jobs 1 run.
        let blocks = preba::util::par::run_jobs(preba::experiments::ALL.len(), |i| {
            let (name, f) = preba::experiments::ALL[i];
            preba::util::bench::capture_begin();
            f(sys);
            (name, preba::util::bench::capture_end())
        });
        for (name, text) in blocks {
            println!("\n########## {name} ##########");
            print!("{text}");
        }
        return Ok(());
    }
    let f = preba::experiments::by_id(id)
        .ok_or_else(|| anyhow::anyhow!("unknown experiment '{id}' (see `preba list`)"))?;
    f(sys);
    Ok(())
}

fn print_run_stats(stats: &preba::metrics::RunStats) {
    let (pre, bat, disp, exec) = stats.breakdown_ms();
    println!(
        "completed {}  throughput {:.1} QPS  mean {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
        stats.completed,
        stats.throughput_qps(),
        stats.mean_ms(),
        stats.p95_ms(),
        stats.e2e_ms.p99()
    );
    println!(
        "breakdown: preprocess {pre:.2} ms | batching {bat:.2} ms | queue {disp:.2} ms | execute {exec:.2} ms"
    );
}
