//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the CPU
//! PJRT client via the `xla` crate.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! One compiled executable per (model, batch, length-bucket) artifact —
//! the server picks the artifact whose batch ≥ the formed batch and pads.

use std::collections::HashMap;

use crate::models::{ArtifactEntry, Manifest};

/// A loaded + compiled artifact with its shape metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub entry: ArtifactEntry,
}

/// PJRT engine owning the client and the executable cache.
///
/// The real driver confines it to the worker thread that owns model
/// execution (Python-free request path, single PJRT context).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, Executable>,
    /// Weight literal sets, keyed by weights file name. Loaded once and
    /// passed as the leading parameters of every execute (large constants
    /// travel as parameters because HLO text elides big literals —
    /// DESIGN.md §4).
    weights: HashMap<String, Vec<xla::Literal>>,
}

impl Engine {
    /// Create a CPU PJRT client over an artifacts directory.
    pub fn new(artifacts_dir: &str) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT: {e:?}"))?;
        Ok(Engine { client, manifest, cache: HashMap::new(), weights: HashMap::new() })
    }

    /// Load (once) the weight literals for an artifact's weights file.
    fn load_weights(&mut self, entry: &ArtifactEntry) -> anyhow::Result<()> {
        let Some(file) = &entry.weights_file else { return Ok(()) };
        if self.weights.contains_key(file) {
            return Ok(());
        }
        let path = self.manifest.dir.join(file);
        let bytes = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("read weights {}: {e}", path.display()))?;
        let total: usize = entry.weight_shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        anyhow::ensure!(
            bytes.len() == total * 4,
            "weights file {} has {} bytes, expected {}",
            file,
            bytes.len(),
            total * 4
        );
        let mut floats = vec![0f32; total];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            floats[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        let mut literals = Vec::with_capacity(entry.weight_shapes.len());
        let mut off = 0usize;
        for shape in &entry.weight_shapes {
            let n: usize = shape.iter().product();
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&floats[off..off + n])
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("weights reshape {shape:?}: {e:?}"))?;
            literals.push(lit);
            off += n;
        }
        self.weights.insert(file.clone(), literals);
        Ok(())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) the artifact registered under `key`.
    pub fn load(&mut self, key: &str) -> anyhow::Result<&Executable> {
        if !self.cache.contains_key(key) {
            let entry = self
                .manifest
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("artifact '{key}' not in manifest"))?
                .clone();
            let path = self.manifest.path_of(&entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {key}: {e:?}"))?;
            self.cache.insert(key.to_string(), Executable { exe, entry });
        }
        Ok(&self.cache[key])
    }

    /// Number of compiled executables held.
    pub fn loaded(&self) -> usize {
        self.cache.len()
    }

    /// Execute artifact `key` on f32 inputs (shape-checked against the
    /// manifest). Returns the flattened f32 outputs.
    ///
    /// Inputs shorter than the artifact's input size are zero-padded (the
    /// caller slices the outputs back down — batch padding).
    pub fn execute_f32(&mut self, key: &str, inputs: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.load(key)?;
        let entry = self.cache[key].entry.clone();
        self.load_weights(&entry)?;
        let ex = &self.cache[key];
        anyhow::ensure!(
            inputs.len() == entry.inputs.len(),
            "artifact '{key}' expects {} data inputs, got {}",
            entry.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            let shape = &entry.inputs[i];
            let want: usize = shape.iter().product();
            anyhow::ensure!(
                data.len() <= want,
                "input {i} of '{key}': {} elements exceeds shape {:?}",
                data.len(),
                shape
            );
            let mut padded = data.clone();
            padded.resize(want, 0.0);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&padded)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape input {i}: {e:?}"))?;
            literals.push(lit);
        }
        // Leading weight parameters (by reference), then the data inputs.
        let empty: Vec<xla::Literal> = Vec::new();
        let weight_lits = match &entry.weights_file {
            Some(f) => &self.weights[f],
            None => &empty,
        };
        let args: Vec<&xla::Literal> = weight_lits.iter().chain(literals.iter()).collect();
        let result = ex
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("execute {key}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {key}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack the output tuple.
        let n_out = ex.entry.outputs.len();
        let elems = result.to_tuple().map_err(|e| anyhow::anyhow!("untuple {key}: {e:?}"))?;
        anyhow::ensure!(
            elems.len() == n_out,
            "artifact '{key}': manifest says {n_out} outputs, HLO returned {}",
            elems.len()
        );
        let mut outs = Vec::with_capacity(n_out);
        for (i, lit) in elems.into_iter().enumerate() {
            let v = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("output {i} of {key} not f32: {e:?}"))?;
            outs.push(v);
        }
        Ok(outs)
    }

    /// Find the smallest lowered batch ≥ `want` for a model (for padding),
    /// or the largest available if `want` exceeds them all.
    pub fn pick_batch(&self, name: &str, want: usize) -> Option<usize> {
        let batches = self.manifest.batches_for(name);
        batches.iter().copied().find(|&b| b >= want).or(batches.last().copied())
    }
}

#[cfg(test)]
mod tests {
    // Engine tests needing real artifacts live in
    // rust/tests/integration_runtime.rs (they require `make artifacts`).
    use super::*;

    #[test]
    fn missing_artifacts_dir_errors_cleanly() {
        let err = match Engine::new("/no/such/dir") {
            Ok(_) => panic!("engine created from nonexistent dir"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }
}
