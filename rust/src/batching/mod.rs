//! PREBA's dynamic batching system (paper §4.3) plus the static baseline.
//!
//! Two hyperparameters govern a batching queue:
//! * `Batch_max` — largest batch the system will construct. Optimal value
//!   is `Batch_knee` (paper §3.2): bigger batches add latency with ~no
//!   throughput gain.
//! * `Time_queue` — longest time a request may wait in the queue while a
//!   batch forms. PREBA sets it to `Time_knee / n_vGPUs` so that while the
//!   n vGPUs each execute a batch (~`Time_knee`), the batcher forms ~n new
//!   batches (§4.3 "Analytical model based estimation").
//!
//! Variable-length audio is bucketized into non-overlapping 2.5 s windows,
//! one queue per bucket, each with its own `Batch_max` (= the bucket's
//! profiled `Batch_knee`). Undersized timeout batches merge requests from
//! adjacent buckets, capped by the `Batch_max` of the longest input in the
//! merged batch (§4.3 last paragraph, Fig 16).

pub mod bucket;
pub mod policy;
pub mod queue;

pub use bucket::Bucketizer;
pub use policy::{BatchPolicy, QueueParams};
pub use queue::{Batch, DynamicBatcher, Request};

/// Unique request id.
pub type ReqId = u64;
