//! Batching policies: PREBA's profiled dynamic policy vs the static
//! baseline (paper §4.3, ablation §6.4).

use crate::clock::{secs, Nanos};
use crate::mig::ServiceModel;
use crate::models::ModelSpec;

use super::bucket::Bucketizer;

/// Per-queue hyperparameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueParams {
    /// Largest batch this queue will form (`Batch_max`).
    pub batch_max: usize,
    /// Longest a head-of-line request may wait (`Time_queue`).
    pub time_queue: Nanos,
}

/// How the per-bucket queue parameters are chosen.
#[derive(Debug, Clone)]
pub enum BatchPolicy {
    /// One fixed (Batch_max, Time_queue) for every bucket — the baseline
    /// a naive MIG deployment uses (ablation "Base").
    Static(QueueParams),
    /// PREBA: per-bucket `Batch_max = Batch_knee` from offline profiling,
    /// `Time_queue = Time_knee / n_vgpus`.
    Dynamic { per_bucket: Vec<QueueParams> },
}

impl BatchPolicy {
    /// Parameters for a bucket.
    pub fn params(&self, bucket: usize) -> QueueParams {
        match self {
            BatchPolicy::Static(p) => *p,
            BatchPolicy::Dynamic { per_bucket } => {
                per_bucket[bucket.min(per_bucket.len().saturating_sub(1))]
            }
        }
    }

    /// Construct PREBA's dynamic policy directly from the calibrated
    /// service model (the paper does this with a few minutes of offline
    /// profiling; `profiler::knee_table` does the measured equivalent and
    /// agrees — see `profiler::tests`).
    pub fn dynamic_from_model(
        spec: &ModelSpec,
        sm: &ServiceModel,
        buckets: &Bucketizer,
        n_vgpus: usize,
    ) -> BatchPolicy {
        let per_bucket = (0..buckets.n_buckets())
            .map(|b| {
                let len = buckets.repr_len(b);
                let knee = sm.knee(len);
                let time_knee = sm.exec_secs(knee, len);
                QueueParams {
                    batch_max: knee,
                    time_queue: secs(time_knee / n_vgpus as f64),
                }
            })
            .collect();
        let _ = spec;
        BatchPolicy::Dynamic { per_bucket }
    }

    /// The largest Batch_max across buckets (used to size executables).
    pub fn max_batch(&self) -> usize {
        match self {
            BatchPolicy::Static(p) => p.batch_max,
            BatchPolicy::Dynamic { per_bucket } => {
                per_bucket.iter().map(|p| p.batch_max).max().unwrap_or(1)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;

    #[test]
    fn dynamic_policy_time_queue_divides_by_vgpus() {
        let spec = ModelId::CitriNet.spec();
        let sm = ServiceModel::new(spec, 1);
        let buckets = Bucketizer::new(2.5, 25.0);
        let p7 = BatchPolicy::dynamic_from_model(spec, &sm, &buckets, 7);
        let p1 = BatchPolicy::dynamic_from_model(spec, &sm, &buckets, 1);
        let q7 = p7.params(0);
        let q1 = p1.params(0);
        assert_eq!(q7.batch_max, q1.batch_max);
        // Time_queue scales as 1/n_vgpus.
        let ratio = q1.time_queue as f64 / q7.time_queue as f64;
        assert!((ratio - 7.0).abs() < 0.01, "ratio={ratio}");
    }

    #[test]
    fn dynamic_batch_max_shrinks_with_length() {
        let spec = ModelId::ConformerDefault.spec();
        let sm = ServiceModel::new(spec, 1);
        let buckets = Bucketizer::new(2.5, 25.0);
        let p = BatchPolicy::dynamic_from_model(spec, &sm, &buckets, 7);
        let first = p.params(0).batch_max;
        let last = p.params(9).batch_max;
        assert!(first > last, "knee should shrink with length: {first} vs {last}");
    }

    #[test]
    fn static_same_everywhere() {
        let p = BatchPolicy::Static(QueueParams { batch_max: 32, time_queue: 1000 });
        assert_eq!(p.params(0), p.params(5));
        assert_eq!(p.max_batch(), 32);
    }

    #[test]
    fn audio_time_queue_near_5ms_for_7_vgpus() {
        // Paper: Time_knee ~35 ms, so Time_queue ~ 5 ms on 1g.5gb(7x).
        let spec = ModelId::ConformerSmall.spec();
        let sm = ServiceModel::new(spec, 1);
        let buckets = Bucketizer::new(2.5, 25.0);
        let p = BatchPolicy::dynamic_from_model(spec, &sm, &buckets, 7);
        let tq_ms = p.params(1).time_queue as f64 / 1e6;
        assert!((tq_ms - 5.0).abs() < 1.5, "tq={tq_ms} ms");
    }
}
