//! The batching queues and batch-formation logic (paper §4.3, Fig 16).

use std::collections::VecDeque;

use crate::clock::Nanos;
use crate::models::ModelId;

use super::bucket::Bucketizer;
use super::policy::BatchPolicy;
use super::ReqId;

/// An inference request flowing through the server.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: ReqId,
    pub model: ModelId,
    /// Arrival at the server frontend.
    pub arrival: Nanos,
    /// When preprocessing finished and the request entered its queue.
    pub enqueued: Nanos,
    /// Audio length in seconds (0 for vision).
    pub len_s: f64,
}

/// A formed batch, ready for model execution on a vGPU.
#[derive(Debug, Clone)]
pub struct Batch {
    pub model: ModelId,
    pub requests: Vec<Request>,
    /// When the batch was formed.
    pub formed: Nanos,
    /// Longest member length (the batch pads to this).
    pub max_len_s: f64,
    /// Bucket the batch was formed from (diagnostics).
    pub bucket: usize,
    /// True if requests from adjacent buckets were merged in.
    pub merged: bool,
}

impl Batch {
    pub fn size(&self) -> usize {
        self.requests.len()
    }
}

/// Why a batch was released (diagnostics / tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReleaseReason {
    /// Queue reached `Batch_max`.
    Full,
    /// Head-of-line request hit `Time_queue`.
    Timeout,
}

/// PREBA's multi-queue dynamic batcher for one model.
///
/// One FIFO queue per length bucket; vision models use the single
/// `Bucketizer::fixed()` bucket. Formation rules:
/// * a queue reaching its `Batch_max` releases immediately;
/// * a head-of-line request older than `Time_queue` releases the queue's
///   contents, merging from adjacent buckets (nearest-first) if the batch
///   is undersized — capped by the `Batch_max` of the *longest* request in
///   the merged batch (paper §4.3).
#[derive(Debug)]
pub struct DynamicBatcher {
    model: ModelId,
    buckets: Bucketizer,
    policy: BatchPolicy,
    queues: Vec<VecDeque<Request>>,
    merge_adjacent: bool,
    /// Recycled request vectors (capacity retained) so steady-state batch
    /// formation allocates nothing; bounded by `MAX_SPARE_VECS`.
    spare: Vec<Vec<Request>>,
    // counters for invariants/diagnostics
    enqueued: u64,
    released: u64,
}

/// Upper bound on pooled request vectors — more than the deepest in-flight
/// population any config reaches (7 vGPUs × a few queued batches each).
const MAX_SPARE_VECS: usize = 64;

impl DynamicBatcher {
    pub fn new(
        model: ModelId,
        buckets: Bucketizer,
        policy: BatchPolicy,
        merge_adjacent: bool,
    ) -> DynamicBatcher {
        let n = buckets.n_buckets();
        DynamicBatcher {
            model,
            buckets,
            policy,
            queues: (0..n).map(|_| VecDeque::new()).collect(),
            merge_adjacent,
            spare: Vec::new(),
            enqueued: 0,
            released: 0,
        }
    }

    pub fn model(&self) -> ModelId {
        self.model
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    pub fn bucketizer(&self) -> &Bucketizer {
        &self.buckets
    }

    /// Total requests waiting across all queues.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    /// Requests enqueued minus released (must equal `pending`).
    pub fn balance(&self) -> u64 {
        self.enqueued - self.released
    }

    /// Add a preprocessed request to its bucket queue.
    pub fn enqueue(&mut self, req: Request) {
        debug_assert_eq!(req.model, self.model);
        let b = self.buckets.bucket_of(req.len_s);
        self.queues[b].push_back(req);
        self.enqueued += 1;
    }

    /// Earliest absolute deadline at which some queue must be flushed
    /// (head-of-line enqueue time + its bucket's Time_queue).
    pub fn next_deadline(&self) -> Option<Nanos> {
        self.queues
            .iter()
            .enumerate()
            .filter_map(|(b, q)| {
                q.front().map(|r| r.enqueued.saturating_add(self.policy.params(b).time_queue))
            })
            .min()
    }

    /// Try to form one batch at time `now`. Returns `None` when no queue
    /// is full and no deadline has passed. Call repeatedly to drain.
    pub fn try_form(&mut self, now: Nanos) -> Option<(Batch, ReleaseReason)> {
        // 1. Any full queue releases immediately (prefer the fullest
        //    relative to its Batch_max, then lowest bucket for determinism).
        let mut full: Option<(usize, f64)> = None;
        for (b, q) in self.queues.iter().enumerate() {
            let bm = self.policy.params(b).batch_max;
            if q.len() >= bm {
                let ratio = q.len() as f64 / bm as f64;
                if full.map(|(_, r)| ratio > r).unwrap_or(true) {
                    full = Some((b, ratio));
                }
            }
        }
        if let Some((b, _)) = full {
            return Some((self.release(b, now, false), ReleaseReason::Full));
        }

        // 2. Any expired head-of-line request releases its queue, with
        //    adjacent-bucket merging.
        let expired = self
            .queues
            .iter()
            .enumerate()
            .filter_map(|(b, q)| {
                let head = q.front()?;
                let deadline = head.enqueued.saturating_add(self.policy.params(b).time_queue);
                (deadline <= now).then_some((b, head.enqueued))
            })
            .min_by_key(|&(_, t)| t);
        if let Some((b, _)) = expired {
            return Some((self.release(b, now, self.merge_adjacent), ReleaseReason::Timeout));
        }
        None
    }

    /// Release up to `Batch_max` requests from bucket `b`, merging from
    /// adjacent buckets when undersized (and allowed).
    fn release(&mut self, b: usize, now: Nanos, merge: bool) -> Batch {
        let mut batch_max = self.policy.params(b).batch_max;
        let mut reqs: Vec<Request> = self.spare.pop().unwrap_or_default();
        debug_assert!(reqs.is_empty());
        reqs.reserve(batch_max);
        while reqs.len() < batch_max {
            match self.queues[b].pop_front() {
                Some(r) => reqs.push(r),
                None => break,
            }
        }
        let mut merged = false;
        if merge && reqs.len() < batch_max {
            // Pull from adjacent buckets, nearest first. The effective
            // Batch_max is re-derived from the longest input in the batch:
            // merging a longer request can only *shrink* the cap (paper:
            // "the batch size does not exceed the Batch_max of the longest
            // input within the batch").
            for nb in self.buckets.adjacent(b) {
                // Cap that would apply once a request from `nb` joins the
                // batch: merging a *longer* input re-derives Batch_max from
                // the longest member, which can only shrink the cap. If the
                // batch already holds that many, skip this bucket entirely
                // (never trim an already-formed batch).
                let cap_if_merge =
                    if nb > b {
                        batch_max.min(self.policy.params(nb).batch_max)
                    } else {
                        batch_max
                    };
                while reqs.len() < cap_if_merge {
                    let Some(r) = self.queues[nb].pop_front() else { break };
                    merged = true;
                    reqs.push(r);
                    if nb > b {
                        batch_max = cap_if_merge;
                    }
                }
                if reqs.len() >= batch_max {
                    break;
                }
            }
        }
        debug_assert!(!reqs.is_empty(), "release on empty bucket");
        self.released += reqs.len() as u64;
        let max_len_s = reqs.iter().map(|r| r.len_s).fold(0.0, f64::max);
        Batch { model: self.model, requests: reqs, formed: now, max_len_s, bucket: b, merged }
    }

    /// Return a completed batch's request vector to the spare pool so the
    /// next `release` reuses its allocation. Callers that drop batches
    /// without recycling stay correct — they just allocate.
    pub fn recycle(&mut self, batch: Batch) {
        if self.spare.len() < MAX_SPARE_VECS {
            let mut v = batch.requests;
            v.clear();
            self.spare.push(v);
        }
    }

    /// Swap in a new policy (e.g. after a MIG reconfiguration changed the
    /// vGPU count, which moves every bucket's `Time_queue = Time_knee/n`)
    /// and re-enqueue all pending requests under it. Original `enqueued`
    /// times are preserved so deadlines stay honest, and global FIFO by
    /// `(enqueued, id)` is restored across buckets. Shared by both DES
    /// drivers' reconfig paths — keep them from diverging.
    pub fn rebuild(&mut self, policy: BatchPolicy, now: Nanos) {
        let mut pending: Vec<Request> = Vec::with_capacity(self.pending());
        for b in self.flush(now) {
            pending.extend(b.requests);
        }
        pending.sort_by_key(|r| (r.enqueued, r.id));
        self.policy = policy;
        for r in pending {
            self.enqueue(r);
        }
    }

    /// Drain everything immediately (server shutdown).
    pub fn flush(&mut self, now: Nanos) -> Vec<Batch> {
        let mut out = Vec::new();
        for b in 0..self.queues.len() {
            while !self.queues[b].is_empty() {
                out.push(self.release(b, now, false));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::policy::QueueParams;
    use crate::clock::millis;

    fn mk_req(id: u64, enq: Nanos, len_s: f64) -> Request {
        Request { id, model: ModelId::CitriNet, arrival: enq, enqueued: enq, len_s }
    }

    fn static_batcher(batch_max: usize, time_queue: Nanos) -> DynamicBatcher {
        DynamicBatcher::new(
            ModelId::CitriNet,
            Bucketizer::new(2.5, 25.0),
            BatchPolicy::Static(QueueParams { batch_max, time_queue }),
            true,
        )
    }

    #[test]
    fn releases_on_full() {
        let mut b = static_batcher(4, millis(100.0));
        for i in 0..3 {
            b.enqueue(mk_req(i, 0, 1.0));
            assert!(b.try_form(0).is_none());
        }
        b.enqueue(mk_req(3, 0, 1.0));
        let (batch, why) = b.try_form(0).unwrap();
        assert_eq!(why, ReleaseReason::Full);
        assert_eq!(batch.size(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn releases_on_timeout() {
        let mut b = static_batcher(8, millis(10.0));
        b.enqueue(mk_req(0, 0, 1.0));
        b.enqueue(mk_req(1, millis(2.0), 1.0));
        assert!(b.try_form(millis(9.0)).is_none());
        let (batch, why) = b.try_form(millis(10.0)).unwrap();
        assert_eq!(why, ReleaseReason::Timeout);
        assert_eq!(batch.size(), 2);
    }

    #[test]
    fn next_deadline_tracks_head_of_line() {
        let mut b = static_batcher(8, millis(10.0));
        assert_eq!(b.next_deadline(), None);
        b.enqueue(mk_req(0, millis(5.0), 1.0));
        b.enqueue(mk_req(1, millis(1.0), 4.0)); // different bucket, earlier
        assert_eq!(b.next_deadline(), Some(millis(11.0)));
    }

    #[test]
    fn buckets_batch_separately() {
        let mut b = static_batcher(2, millis(100.0));
        b.enqueue(mk_req(0, 0, 1.0)); // bucket 0
        b.enqueue(mk_req(1, 0, 6.0)); // bucket 2 (Fig 16 example)
        assert!(b.try_form(0).is_none(), "no bucket is full");
        b.enqueue(mk_req(2, 0, 1.2)); // bucket 0 now full
        let (batch, _) = b.try_form(0).unwrap();
        assert_eq!(batch.bucket, 0);
        assert_eq!(batch.size(), 2);
        assert!(batch.requests.iter().all(|r| r.len_s < 2.5));
    }

    #[test]
    fn timeout_merges_adjacent_nearest_first() {
        let mut b = static_batcher(4, millis(10.0));
        b.enqueue(mk_req(0, 0, 6.0)); // bucket 2
        b.enqueue(mk_req(1, 0, 3.0)); // bucket 1 (nearest)
        b.enqueue(mk_req(2, 0, 9.0)); // bucket 3
        let (batch, why) = b.try_form(millis(10.0)).unwrap();
        assert_eq!(why, ReleaseReason::Timeout);
        assert!(batch.merged);
        assert_eq!(batch.size(), 3);
        assert_eq!(batch.max_len_s, 9.0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn merge_respects_longest_member_batch_max() {
        // Dynamic policy where long buckets have smaller Batch_max.
        let per_bucket = vec![
            QueueParams { batch_max: 8, time_queue: millis(10.0) }, // [0,2.5)
            QueueParams { batch_max: 2, time_queue: millis(10.0) }, // [2.5,5)
        ];
        let mut b = DynamicBatcher::new(
            ModelId::CitriNet,
            Bucketizer::new(2.5, 5.0),
            BatchPolicy::Dynamic { per_bucket },
            true,
        );
        // 3 short requests time out with 1 long request waiting in
        // bucket 1 (below its own Batch_max of 2, so it is not released
        // on the full-queue path first).
        b.enqueue(mk_req(0, 0, 1.0));
        b.enqueue(mk_req(1, 0, 1.1));
        b.enqueue(mk_req(2, 0, 1.2));
        b.enqueue(mk_req(3, millis(1.0), 3.0));
        let (batch, _) = b.try_form(millis(10.0)).unwrap();
        // Bucket 0's Batch_max is 8, but merging the long request would
        // cap the batch at bucket 1's Batch_max = 2 — and the batch
        // already holds 3, so the long request must NOT be merged.
        assert!(!batch.merged, "must not merge past the longest-member cap");
        assert_eq!(batch.size(), 3);
        assert_eq!(b.pending(), 1);

        // Conversely: a single timed-out short request merges the long
        // one and the cap shrinks to 2.
        let per_bucket = vec![
            QueueParams { batch_max: 8, time_queue: millis(10.0) },
            QueueParams { batch_max: 2, time_queue: millis(10.0) },
        ];
        let mut b = DynamicBatcher::new(
            ModelId::CitriNet,
            Bucketizer::new(2.5, 5.0),
            BatchPolicy::Dynamic { per_bucket },
            true,
        );
        b.enqueue(mk_req(0, 0, 1.0));
        b.enqueue(mk_req(1, millis(1.0), 3.0));
        let (batch, _) = b.try_form(millis(10.0)).unwrap();
        assert!(batch.merged);
        assert_eq!(batch.size(), 2);
        assert_eq!(batch.max_len_s, 3.0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn fifo_within_bucket() {
        let mut b = static_batcher(3, millis(10.0));
        for i in 0..3 {
            b.enqueue(mk_req(i, i, 1.0));
        }
        let (batch, _) = b.try_form(5).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn balance_invariant() {
        let mut b = static_batcher(4, millis(10.0));
        for i in 0..10 {
            b.enqueue(mk_req(i, 0, (i % 5) as f64));
        }
        let mut out = 0;
        while let Some((batch, _)) = b.try_form(millis(100.0)) {
            out += batch.size();
        }
        assert_eq!(out, 10);
        assert_eq!(b.balance(), 0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn recycle_reuses_request_vec_allocation() {
        let mut b = static_batcher(4, millis(100.0));
        for i in 0..4 {
            b.enqueue(mk_req(i, 0, 1.0));
        }
        let (batch, _) = b.try_form(0).unwrap();
        let cap = batch.requests.capacity();
        let ptr = batch.requests.as_ptr();
        b.recycle(batch);
        for i in 4..8 {
            b.enqueue(mk_req(i, 0, 1.0));
        }
        let (batch2, _) = b.try_form(0).unwrap();
        assert_eq!(batch2.size(), 4);
        assert_eq!(batch2.requests.as_ptr(), ptr, "allocation not reused");
        assert!(batch2.requests.capacity() >= cap);
        let ids: Vec<u64> = batch2.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![4, 5, 6, 7]);
    }

    #[test]
    fn rebuild_preserves_requests_and_enqueue_times() {
        let mut b = static_batcher(8, millis(50.0));
        for i in 0..5 {
            b.enqueue(mk_req(i, millis(i as f64), (i % 3) as f64 * 4.0));
        }
        b.rebuild(
            BatchPolicy::Static(QueueParams { batch_max: 3, time_queue: millis(10.0) }),
            millis(5.0),
        );
        assert_eq!(b.pending(), 5);
        assert_eq!(b.balance(), 5);
        // The first queue to fill under the new Batch_max releases; its
        // members keep their original enqueue times (FIFO preserved).
        b.enqueue(mk_req(5, millis(6.0), 0.0));
        let (batch, why) = b.try_form(millis(6.0)).unwrap();
        assert_eq!(why, ReleaseReason::Full);
        assert_eq!(batch.size(), 3);
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 3, 5], "short-bucket FIFO by enqueue time");
        assert_eq!(batch.requests[0].enqueued, millis(0.0));
    }

    #[test]
    fn flush_drains_all() {
        let mut b = static_batcher(100, millis(1000.0));
        for i in 0..7 {
            b.enqueue(mk_req(i, 0, (i as f64) * 3.0));
        }
        let batches = b.flush(millis(1.0));
        let total: usize = batches.iter().map(Batch::size).sum();
        assert_eq!(total, 7);
        assert_eq!(b.pending(), 0);
    }
}
