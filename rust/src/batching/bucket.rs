//! Audio-length bucketization (paper §4.3, Fig 16).
//!
//! Input lengths are split into non-overlapping windows of
//! `window_s` seconds: `[0, 2.5)`, `[2.5, 5.0)`, ... Requests land in the
//! queue of their bucket; a bucket's representative length (used for
//! profiling and for batch-execution padding) is the window's upper edge,
//! because a formed batch is padded to its longest member.

/// Maps audio lengths to bucket indices and representative lengths.
#[derive(Debug, Clone)]
pub struct Bucketizer {
    window_s: f64,
    n_buckets: usize,
}

impl Bucketizer {
    /// `window_s`-wide buckets covering `[0, max_s)`.
    pub fn new(window_s: f64, max_s: f64) -> Bucketizer {
        assert!(window_s > 0.0 && max_s > window_s);
        let n = (max_s / window_s).ceil() as usize;
        Bucketizer { window_s, n_buckets: n.max(1) }
    }

    /// Single-bucket bucketizer for fixed-size (vision) inputs.
    pub fn fixed() -> Bucketizer {
        Bucketizer { window_s: f64::INFINITY, n_buckets: 1 }
    }

    pub fn n_buckets(&self) -> usize {
        self.n_buckets
    }

    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    /// Bucket index of a length. Buckets are upper-edge inclusive —
    /// `(0, 2.5], (2.5, 5.0], ...` — so an input exactly at a window edge
    /// pads to that edge, not to the next one (a 2.5 s input in a
    /// `[2.5, 5)` bucket would execute padded to 5 s, wasting half the
    /// batch's compute).
    pub fn bucket_of(&self, len_s: f64) -> usize {
        if self.n_buckets == 1 {
            return 0;
        }
        let idx = (len_s / self.window_s).ceil() as isize - 1;
        idx.clamp(0, self.n_buckets as isize - 1) as usize
    }

    /// Representative (upper-edge) length of a bucket; used for profiling
    /// and padding. For the fixed bucketizer this is 0 (ignored).
    pub fn repr_len(&self, bucket: usize) -> f64 {
        if self.n_buckets == 1 && self.window_s.is_infinite() {
            return 0.0;
        }
        self.window_s * (bucket + 1) as f64
    }

    /// Buckets adjacent to `b`, nearest first (for merge; paper Fig 16).
    pub fn adjacent(&self, b: usize) -> Vec<usize> {
        let mut out = Vec::new();
        for d in 1..self.n_buckets {
            if b >= d {
                out.push(b - d);
            }
            if b + d < self.n_buckets {
                out.push(b + d);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_buckets() {
        // Fig 16: 2.5 s windows; a 6 s input falls in the third bucket.
        let b = Bucketizer::new(2.5, 25.0);
        assert_eq!(b.n_buckets(), 10);
        assert_eq!(b.bucket_of(6.0), 2);
        assert_eq!(b.bucket_of(0.0), 0);
        assert_eq!(b.bucket_of(2.49), 0);
        assert_eq!(b.bucket_of(2.5), 0); // edge is inclusive: pads to 2.5
        assert_eq!(b.bucket_of(2.51), 1);
        assert_eq!(b.bucket_of(999.0), 9); // clamped
    }

    #[test]
    fn repr_len_is_upper_edge() {
        let b = Bucketizer::new(2.5, 25.0);
        assert_eq!(b.repr_len(0), 2.5);
        assert_eq!(b.repr_len(2), 7.5);
    }

    #[test]
    fn fixed_single_bucket() {
        let b = Bucketizer::fixed();
        assert_eq!(b.n_buckets(), 1);
        assert_eq!(b.bucket_of(17.0), 0);
        assert_eq!(b.repr_len(0), 0.0);
        assert!(b.adjacent(0).is_empty());
    }

    #[test]
    fn adjacency_nearest_first() {
        let b = Bucketizer::new(2.5, 10.0); // 4 buckets
        assert_eq!(b.adjacent(1), vec![0, 2, 3]);
        assert_eq!(b.adjacent(0), vec![1, 2, 3]);
        assert_eq!(b.adjacent(3), vec![2, 1, 0]);
    }
}
