#!/usr/bin/env bash
# Arm the CI perf gates from a green run's bench artifact.
#
# The CI `perf` job uploads BENCH_pr<N>.json (the `bench-results`
# artifact) on every run, but the gates stay disarmed while
# rust/benches/perf_baseline.json holds nulls. Download the artifact
# from the first green main-branch run and point this script at it:
#
#   scripts/arm_perf_gates.sh path/to/BENCH_pr12.json
#
# It copies hotpath.events_per_sec, cluster.events_per_sec,
# cluster.joules_per_query, cluster.availability_frac, the streamed
# trace-day probe's cluster.trace_1m_events_per_sec /
# cluster.trace_1m_peak_rss_mb, the interference sizing A/B's
# cluster.interference_violation_gap, the planner-stack probe's
# cluster.planner_gap / cluster.planner_greedy_p99_us and the
# obs-capture probe's cluster.obs_overhead_frac into
# rust/benches/perf_baseline.json (preserving the note), prints the
# before/after values, and leaves the change for you to review and
# commit.
set -euo pipefail

if [ $# -ne 1 ] || [ ! -f "$1" ]; then
    echo "usage: $0 BENCH_pr<N>.json   (a CI bench-results artifact)" >&2
    exit 2
fi

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
baseline="$repo_root/rust/benches/perf_baseline.json"

python3 - "$1" "$baseline" <<'EOF'
import json, sys

bench_path, baseline_path = sys.argv[1], sys.argv[2]
bench = json.load(open(bench_path))
baseline = json.load(open(baseline_path))

updates = {
    "events_per_sec": bench["hotpath"]["events_per_sec"],
    "cluster_events_per_sec": bench["cluster"]["events_per_sec"],
    "cluster_joules_per_query": bench["cluster"].get("joules_per_query"),
    "cluster_availability_frac": bench["cluster"].get("availability_frac"),
    "cluster_1m_events_per_sec": bench["cluster"].get("trace_1m_events_per_sec"),
    "cluster_1m_peak_rss_mb": bench["cluster"].get("trace_1m_peak_rss_mb"),
    "cluster_interference_violation_gap": bench["cluster"].get("interference_violation_gap"),
    "cluster_planner_gap": bench["cluster"].get("planner_gap"),
    "cluster_planner_greedy_p99_us": bench["cluster"].get("planner_greedy_p99_us"),
    "cluster_obs_overhead_frac": bench["cluster"].get("obs_overhead_frac"),
}
for key, value in updates.items():
    if value is None:
        print(f"{key}: artifact has no measurement; leaving {baseline.get(key)}")
        continue
    print(f"{key}: {baseline.get(key)} -> {value}")
    baseline[key] = value

with open(baseline_path, "w") as f:
    json.dump(baseline, f, indent=2)
    f.write("\n")
print(f"\nwrote {baseline_path} — review with `git diff` and commit to arm the gates")
EOF
