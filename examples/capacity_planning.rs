//! Capacity planning: use the library's calibrated models to answer the
//! AIaaS operator's question — which MIG partition + batching policy
//! sustains a target workload within an SLA, and at what cost?
//!
//! Sweeps the three paper partitions × both batching policies for a
//! given model and SLA, reporting SLA-bounded throughput, energy
//! efficiency, and TCO — the paper's §6 metrics as a planning tool.
//!
//! Run: `cargo run --release --example capacity_planning [-- model sla_ms]`

use preba::config::PrebaConfig;
use preba::experiments::support;
use preba::metrics::{PowerModel, TcoModel};
use preba::mig::MigConfig;
use preba::models::ModelId;
use preba::server::{PolicyKind, PreprocMode};
use preba::util::table::{num, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args
        .first()
        .and_then(|s| ModelId::parse(s))
        .unwrap_or(ModelId::ConformerDefault);
    let sla_ms: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(100.0);
    let sys = PrebaConfig::new();
    let pm = PowerModel::new(&sys.power);
    let tco = TcoModel::new(&sys.tco);

    println!("capacity plan for {} under p95 <= {sla_ms} ms (PREBA DPU preprocessing)", model.display());
    let mut t = Table::new(&[
        "partition", "policy", "QPS @SLA", "p95 ms", "QPS/W", "Mqueries/$",
    ]);
    let mut best: Option<(f64, String)> = None;
    for mig in MigConfig::ALL {
        for policy in [PolicyKind::Static, PolicyKind::Dynamic] {
            let (qps, p95) = support::max_qps_under_sla(
                model, mig, PreprocMode::Dpu, policy, sla_ms, 4000, &sys,
            );
            // Power at that operating point (approximate utilizations).
            let gpu_util = 0.85;
            let power = pm.power(0.2, gpu_util, Some(0.5));
            let eff = pm.qpj(qps, &power);
            let cost = tco.evaluate(qps, &power, true).queries_per_usd / 1e6;
            let label = format!("{} + {:?}", mig.name(), policy);
            if best.as_ref().map(|(b, _)| qps > *b).unwrap_or(true) {
                best = Some((qps, label.clone()));
            }
            t.row(&[
                mig.name().to_string(),
                format!("{policy:?}"),
                num(qps),
                num(p95),
                num(eff),
                num(cost),
            ]);
        }
    }
    t.print();
    let (qps, label) = best.unwrap();
    println!("\nrecommended: {label} ({qps:.0} QPS within SLA)");
    Ok(())
}
